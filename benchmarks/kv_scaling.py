"""Fig 14/15 + Obs 8 — KV growth linearity and the Reasoning Cliff: the OSL
at which decode KV exhausts HBM, and the batch size at which the cliff moves
into the *prefill* phase (admission stalls)."""
from repro.configs.paper_models import DS_DISTILL_8B
from repro.configs.registry import get_config
from repro.core import perf_model as pm
from repro.scenario import ModelRef, Scenario, WorkerGroup

from benchmarks._common import emit


def run():
    rows = []
    cfg8 = DS_DISTILL_8B
    for osl in (1000, 5000, 20000):
        rows.append(emit(f"kv_scaling/8b/decode_kv_gb/osl={osl}",
                         round(cfg8.kv_bytes_per_token(2) * osl / 1e9, 2),
                         "linear in OSL (Fig 15b)"))
    l405 = get_config("llama3-405b")
    cap = pm.kv_capacity_tokens(l405, pm.ParallelismPlan(tp=8), pm.H200)
    rows.append(emit("kv_scaling/405b/tp8_kv_capacity_tokens", cap,
                     "8xH200 pooled"))
    for bs in (128, 512, 2048):
        # tokens of prompt admitted before the pool fills (cliff-in-prefill)
        isl, osl = 105, 6800
        fits = cap // (isl + osl)
        cliff = "decode" if bs <= fits else "prefill(admission-stalled)"
        rows.append(emit(f"kv_scaling/405b/cliff_phase/bs={bs}", cliff,
                         f"fits={fits} concurrent reasoning requests"))

    # engine-level: the same cliff dynamically (scaled)
    eng = Scenario(
        name="kv-scaling-cliff", model=ModelRef("ds-distill-8b"),
        fleet=(WorkerGroup(role="colocated", count=1, max_seqs=4096,
                           admission="naive"),)).to_engine()
    capacity = eng.alloc.n_pages * eng.alloc.page_size
    big = capacity // 3
    for _ in range(12):
        eng.submit(big // 8, big, arrival=0.0)
    s = eng.run(max_steps=200_000).summary()
    rows.append(emit("kv_scaling/engine/peak_kv", round(s["peak_kv_util"], 3),
                     "saturates during long decode"))
    rows.append(emit("kv_scaling/engine/preemptions", s["preemptions"],
                     "cliff response (recompute)"))
    return rows


if __name__ == "__main__":
    run()
