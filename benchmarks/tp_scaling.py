"""Fig 9 + Obs 5 — TP scaling (planner model): the 32B crossover where TP's
capacity release beats its communication cost; 6.15x TP8-vs-TP1 target."""
from repro.configs.paper_models import (DS_DISTILL_14B, DS_DISTILL_32B,
                                        DS_DISTILL_8B)
from repro.core import perf_model as pm, planner

from benchmarks._common import emit


def run():
    rows = []
    wl = planner.Workload()
    for name, cfg in (("8b", DS_DISTILL_8B), ("14b", DS_DISTILL_14B),
                      ("32b", DS_DISTILL_32B)):
        base = None
        for tp in (1, 2, 4, 8):
            e = planner.estimate(cfg, pm.ParallelismPlan(dp=1, tp=tp),
                                 pm.H200, wl)
            base = base or e.completion_s
            rows.append(emit(f"tp_scaling/{name}/completion_s/tp={tp}",
                             round(e.completion_s, 1), "analytical;H200"))
            rows.append(emit(f"tp_scaling/{name}/speedup/tp={tp}",
                             round(base / e.completion_s, 2),
                             "paper 32B: 6.15x at TP8"))
            rows.append(emit(f"tp_scaling/{name}/kv_capacity_tokens/tp={tp}",
                             e.kv_capacity_tokens, "capacity release"))
    return rows


if __name__ == "__main__":
    run()
