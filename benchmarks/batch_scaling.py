"""Fig 4/5 + Obs 3 — batch-size scaling on an 8-replica DP fleet: aggregate
throughput grows but E2E grows sub-linearly and the per-replica capacity trap
persists (DP does not pool memory)."""
from repro.configs.paper_models import DS_DISTILL_8B
from repro.core import perf_model as pm
from repro.core.router import DPRouter, RouterConfig

from benchmarks._common import emit, reasoning_requests, sim_engine


def run():
    cfg = DS_DISTILL_8B
    plan = pm.ParallelismPlan()
    rows = []
    for bs in (125, 500, 1250):           # paper: 500/2000/5000 over 8 GPUs
        replicas = [sim_engine(cfg, plan, max_seqs=256, admission="naive")
                    for _ in range(8)]
        router = DPRouter(replicas, RouterConfig(policy="round_robin"))
        cap = replicas[0].alloc.n_pages * 16
        for isl, osl in reasoning_requests(bs, seed=3):
            router.submit(int(isl), int(min(osl, cap - isl - 2)), arrival=0.0)
        router.run_all(max_steps=400_000)
        sums = [e.metrics.summary() for e in replicas]
        tput = sum(s["gen_throughput_tok_s"] for s in sums)
        e2e = max(s["e2e_s"]["p50"] for s in sums)
        pre = sum(s["preemptions"] for s in sums)
        scale = "8xH200;DP=8;sim;bs scaled /4 vs paper"
        rows.append(emit(f"batch_scaling/agg_tput_tok_s/bs={bs}",
                         round(tput, 0), scale))
        rows.append(emit(f"batch_scaling/e2e_p50_s/bs={bs}", round(e2e, 1),
                         scale))
        rows.append(emit(f"batch_scaling/preemptions/bs={bs}", pre, scale))
        rows.append(emit(f"batch_scaling/peak_kv/bs={bs}",
                         round(max(s['peak_kv_util'] for s in sums), 3),
                         scale))
    return rows


if __name__ == "__main__":
    run()
