"""Fig 4/5 + Obs 3 — batch-size scaling on an 8-replica DP fleet: aggregate
throughput grows but E2E grows sub-linearly and the per-replica capacity trap
persists (DP does not pool memory)."""
import dataclasses

from repro.scenario import ModelRef, Scenario, Traffic, WorkerGroup

from benchmarks._common import emit, make_cluster

BASE = Scenario(
    name="batch-scaling",
    model=ModelRef("ds-distill-8b"),
    fleet=(WorkerGroup(role="colocated", count=8, admission="naive"),),
    traffic=Traffic(process="closed", workload="reasoning",
                    n_requests=125, osl_cap=2400, seed=3),
    routing="round_robin")


def run():
    rows = []
    for bs in (125, 500, 1250):           # paper: 500/2000/5000 over 8 GPUs
        sc = dataclasses.replace(
            BASE, name=f"batch-scaling-bs{bs}",
            traffic=dataclasses.replace(BASE.traffic, n_requests=bs))
        rt = make_cluster(sc)
        rt.submit_trace(sc.trace())
        m = rt.run(max_steps=3_200_000)
        s = m.summary()
        e2e = m.request_summary()["e2e_s"]["p50"]
        pre = sum(v["preemptions"] for v in s["workers"].values())
        peak = max(v["peak_kv_util"] for v in s["workers"].values())
        scale = "8xH200;DP=8;sim;bs scaled /4 vs paper"
        rows.append(emit(f"batch_scaling/agg_tput_tok_s/bs={bs}",
                         round(s["throughput_tok_s"], 0), scale))
        rows.append(emit(f"batch_scaling/e2e_p50_s/bs={bs}", round(e2e, 1),
                         scale))
        rows.append(emit(f"batch_scaling/preemptions/bs={bs}", pre, scale))
        rows.append(emit(f"batch_scaling/peak_kv/bs={bs}", round(peak, 3),
                         scale))
    return rows


if __name__ == "__main__":
    run()
