"""§Roofline deliverable — the per-(arch x shape x mesh) three-term roofline
table, generated from the dry-run artifacts in experiments/dryrun/."""
import glob
import json
import os
from collections import defaultdict

from benchmarks._common import emit


def load(tag="baseline", directory="experiments/dryrun"):
    cells = []
    for f in sorted(glob.glob(f"{directory}/*__{tag}.json")):
        d = json.load(open(f))
        if "error" in d or "skipped" in d:
            continue
        cells.append(d)
    return cells


def run(tag="baseline"):
    rows = []
    cells = load(tag)
    if not cells:
        rows.append(emit("roofline/status", "no dry-run artifacts",
                         "run: python -m repro.launch.dryrun --all"))
        return rows
    bottleneck_count = defaultdict(int)
    for d in cells:
        r = d["roofline"]
        key = f"{d['arch']}/{d['shape']}/{d['mesh']}"
        rows.append(emit(
            f"roofline/{key}",
            f"c={r['t_compute_s']:.3e}s|m={r['t_memory_s']:.3e}s|"
            f"x={r['t_collective_s']:.3e}s",
            f"bound={r['bottleneck']};useful={r['useful_flop_ratio']:.2f};"
            f"frac={r['roofline_fraction']:.3f}"))
        bottleneck_count[r["bottleneck"]] += 1
    for k, v in sorted(bottleneck_count.items()):
        rows.append(emit(f"roofline/bottleneck_census/{k}", v,
                         f"of {len(cells)} cells"))
    return rows


if __name__ == "__main__":
    run()
