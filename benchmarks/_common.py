"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints ``name,value,derived`` CSV rows (brief format) and
returns its rows for run.py to aggregate. Engine-dynamics benchmarks are thin
``Scenario`` definitions compiled to the virtual-clock engine or cluster
fidelity (``repro.scenario``) with H200 constants (the paper's testbed);
parallelism benchmarks use the planner's analytical model. Workloads are
scaled-down Natural-Reasoning samples so the whole suite completes on one CPU
core in minutes — scaling factors are reported in each row's `derived` field.
"""
from __future__ import annotations

from typing import Dict

from repro.core.engine import InferenceEngine
from repro.scenario import Scenario


def emit(name: str, value, derived: str = "") -> Dict:
    print(f"{name},{value},{derived}", flush=True)
    return {"name": name, "value": value, "derived": derived}


def run_to_completion(eng: InferenceEngine, reqs, cap_tokens: int = 10 ** 9):
    """Submit every (isl, osl) at t=0 and drain the engine. OSLs are clamped
    to ``cap_tokens`` and to what fits the engine's page pool alongside the
    prompt (the fits-alone invariant)."""
    capacity = eng.alloc.n_pages * eng.alloc.page_size
    for isl, osl in reqs:
        osl = min(osl, cap_tokens, max(capacity - isl - 2, 1))
        eng.submit(int(isl), int(osl), arrival=0.0)
    return eng.run(max_steps=400_000).summary()


def run_closed(sc: Scenario, cap_tokens: int = 10 ** 9) -> Dict:
    """Compile a scenario's representative replica and run its closed-loop
    trace to completion (the pre-cluster benchmark mode)."""
    from repro.scenario import requests
    return run_to_completion(sc.to_engine(), requests(sc), cap_tokens)
