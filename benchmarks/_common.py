"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints ``name,value,derived`` CSV rows (brief format) and
returns its rows for run.py to aggregate. Engine-dynamics benchmarks run the
REAL scheduler/allocator under the virtual-clock SimRunner with H200
constants (the paper's testbed); parallelism benchmarks use the planner's
analytical model. Workloads are scaled-down Natural-Reasoning samples so the
whole suite completes on one CPU core in minutes — scaling factors are
reported in each row's `derived` field.
"""
from __future__ import annotations

import sys
import time
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core import perf_model as pm
from repro.core.engine import EngineConfig, InferenceEngine
from repro.core.runner import SimRunner
from repro.data.reasoning import REASONING, sample


def emit(name: str, value, derived: str = "") -> Dict:
    print(f"{name},{value},{derived}", flush=True)
    return {"name": name, "value": value, "derived": derived}


def sim_engine(cfg: ModelConfig, plan: pm.ParallelismPlan, hw=pm.H200, *,
               n_pages: Optional[int] = None, max_seqs: int = 256,
               admission: str = "naive", autotune: bool = False,
               max_batched_tokens: int = 8192, dtype_bytes: int = 2
               ) -> InferenceEngine:
    if n_pages is None:
        cap = pm.kv_capacity_tokens(cfg, plan, hw, dtype_bytes)
        n_pages = max(cap // 16, 64)
    ecfg = EngineConfig(n_pages=n_pages, max_num_seqs=max_seqs,
                        max_num_batched_tokens=max_batched_tokens,
                        chunk_size=512, admission_mode=admission,
                        autotune=autotune)
    return InferenceEngine(cfg, ecfg,
                           SimRunner(cfg, plan, hw, dtype_bytes))


def reasoning_requests(n: int, osl_cap: int = 2400, seed: int = 0):
    return [(isl, min(osl, osl_cap)) for isl, osl in
            sample(REASONING, n, seed=seed)]


def run_to_completion(eng: InferenceEngine, reqs, cap_tokens: int = 10 ** 9):
    capacity = eng.alloc.n_pages * eng.alloc.page_size
    for isl, osl in reqs:
        osl = min(osl, max(capacity - isl - 2, 1))
        eng.submit(int(isl), int(osl), arrival=0.0)
    return eng.run(max_steps=400_000).summary()
