"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints ``name,value,derived`` CSV rows (brief format) and
returns its rows for run.py to aggregate. Engine-dynamics benchmarks are thin
``Scenario`` definitions compiled to the virtual-clock engine or cluster
fidelity (``repro.scenario``) with H200 constants (the paper's testbed);
parallelism benchmarks use the planner's analytical model. Workloads are
scaled-down Natural-Reasoning samples so the whole suite completes on one CPU
core in minutes — scaling factors are reported in each row's `derived` field.
"""
from __future__ import annotations

import os
import sys
from typing import Dict, Optional

from repro.core.engine import InferenceEngine
from repro.scenario import Scenario


def emit(name: str, value, derived: str = "") -> Dict:
    print(f"{name},{value},{derived}", flush=True)
    return {"name": name, "value": value, "derived": derived}


# ------------------------------------------------------------ preflight gate
def preflight(sc: Scenario) -> Scenario:
    """Refuse to run a spec whose static feasibility check reports errors.

    ``Scenario.check()`` returns only error-severity diagnostics; printing
    them and exiting non-zero turns a silently-wrong benchmark (a KV pool
    the workload can never fit, an SLO no hardware meets) into a one-line
    failure at process start."""
    diags = sc.check()
    if diags:
        for d in diags:
            print(f"preflight: {sc.name}: {d.format()}",
                  file=sys.stderr, flush=True)
        sys.exit(2)
    return sc


# ------------------------------------------------------------- trace output
# One writer shared by every run in the process: all event streams are
# concatenated in run order into a single JSONL file (each run ends with a
# ``run_end`` / ``finish`` tail, so the differ's per-run boundaries survive).
_trace_writer = None


def set_trace_out(path: Optional[str]) -> None:
    """Route every subsequent benchmark run's event stream to ``path``
    (None disables tracing and closes the current writer)."""
    global _trace_writer
    from repro.trace import JsonlWriter
    if _trace_writer is not None:
        _trace_writer.close()
    _trace_writer = JsonlWriter(path) if path else None


def close_trace() -> None:
    if _trace_writer is not None:
        _trace_writer.close()


def trace_subscribe(log) -> None:
    """Attach the configured trace writer (if any) to an ``EventLog``."""
    if _trace_writer is not None:
        log.subscribe(_trace_writer)


if os.environ.get("REPRO_TRACE_OUT"):
    set_trace_out(os.environ["REPRO_TRACE_OUT"])


# --------------------------------------------------------- bottleneck report
# ``run.py --report`` / REPRO_OBS_REPORT=1: after each benchmark run, fold
# its event stream through repro.obs and print the bottleneck report (regime
# attribution + latency decomposition). Implemented as a pure subscriber tap
# on the run's EventLog, so enabling it cannot perturb any metric.
_report_enabled = False


def set_report(enabled: bool) -> None:
    global _report_enabled
    _report_enabled = enabled


def _obs_tap(log):
    """Recording tap for the report (None when reporting is off)."""
    if not _report_enabled:
        return None
    rows: list = []
    log.subscribe(rows.append)
    return rows


def _obs_print(rows, title: str) -> None:
    from repro.obs import bottleneck_report, render_text
    print(render_text(bottleneck_report(rows), title=title), flush=True)


if os.environ.get("REPRO_OBS_REPORT"):
    set_report(True)


def run_to_completion(eng: InferenceEngine, reqs, cap_tokens: int = 10 ** 9,
                      title: str = "engine"):
    """Submit every (isl, osl) at t=0 and drain the engine. OSLs are clamped
    to ``cap_tokens`` and to what fits the engine's page pool alongside the
    prompt (the fits-alone invariant)."""
    trace_subscribe(eng.events)
    rows = _obs_tap(eng.events)
    capacity = eng.alloc.n_pages * eng.alloc.page_size
    for isl, osl in reqs:
        osl = min(osl, cap_tokens, max(capacity - isl - 2, 1))
        eng.submit(int(isl), int(osl), arrival=0.0)
    summary = eng.run(max_steps=400_000).summary()
    if rows:
        _obs_print(rows, title)
    return summary


def run_closed(sc: Scenario, cap_tokens: int = 10 ** 9) -> Dict:
    """Compile a scenario's representative replica and run its closed-loop
    trace to completion (the pre-cluster benchmark mode)."""
    from repro.scenario import requests
    preflight(sc)
    return run_to_completion(sc.to_engine(), requests(sc), cap_tokens,
                             title=sc.name)


def run_closed_with_report(sc: Scenario, cap_tokens: int = 10 ** 9):
    """``run_closed`` plus the ``repro.obs`` bottleneck report of the same
    run, unconditionally (benchmarks that publish regime-attribution rows
    need the report as *data*, independent of the ``--report`` console
    toggle). Returns ``(summary, report_dict)``."""
    from repro.obs import bottleneck_report
    from repro.scenario import requests
    preflight(sc)
    eng = sc.to_engine()
    rows: list = []
    eng.events.subscribe(rows.append)
    summary = run_to_completion(eng, requests(sc), cap_tokens, title=sc.name)
    return summary, bottleneck_report(rows)


def make_cluster(sc: Scenario, **kwargs):
    """Preflight-gate a spec and compile its cluster fidelity with the
    trace writer (if configured) and the report tap attached. Cluster
    benchmarks call ``rt.run()`` themselves, so the report prints on the
    stream's own ``run_end`` event (the tap subscribes first, so the full
    stream — run_end included — is already recorded when it fires)."""
    rt = preflight(sc).to_cluster(**kwargs)
    trace_subscribe(rt.events)
    rows = _obs_tap(rt.events)
    if rows is not None:
        def _on_end(ev, _rows=rows, _name=sc.name):
            if ev.kind == "run_end":
                _obs_print(_rows, _name)
        rt.events.subscribe(_on_end)
    return rt
