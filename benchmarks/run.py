"""Benchmark driver — one module per paper table/figure.
Prints ``name,value,derived`` CSV plus per-module wall time.

``--trace-out PATH`` streams every run's typed event log (engine and
cluster fidelities alike) to one JSONL file — replayable through
``python -m repro.trace diff`` to pin down where two builds diverge.

``--report`` (or REPRO_OBS_REPORT=1) prints the ``repro.obs`` bottleneck
report — regime attribution and exact latency decomposition — after each
benchmark run (see docs/obs.md)."""
import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Run the paper-figure benchmark suite.")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="write the typed event stream of every benchmark "
                         "run to PATH as JSONL")
    ap.add_argument("--report", action="store_true",
                    help="print the repro.obs bottleneck report after "
                         "each benchmark run")
    args = ap.parse_args(argv)
    from benchmarks import _common
    if args.trace_out:
        _common.set_trace_out(args.trace_out)
    if args.report:
        _common.set_report(True)
    from benchmarks import (batch_scaling, capacity_trap, disagg_sweep,
                            dp_scaling, frontier, hybrid_sweep, kv_scaling,
                            latency_decoupling, model_scaling,
                            phase_divergence, roofline, tp_scaling)
    modules = [
        ("capacity_trap(Fig2)", capacity_trap),
        ("latency_decoupling(Fig3)", latency_decoupling),
        ("batch_scaling(Fig4-5)", batch_scaling),
        ("dp_scaling(Fig6,8)", dp_scaling),
        ("tp_scaling(Fig9)", tp_scaling),
        ("hybrid_sweep(Fig7)", hybrid_sweep),
        ("frontier(Fig10)", frontier),
        ("model_scaling(Fig11)", model_scaling),
        ("phase_divergence(Fig12-13)", phase_divergence),
        ("kv_scaling(Fig14-15)", kv_scaling),
        ("disagg_sweep(cluster)", disagg_sweep),
        ("roofline(dry-run)", roofline),
    ]
    print("name,value,derived")
    total0 = time.time()
    for name, mod in modules:
        t0 = time.time()
        mod.run()
        print(f"_timing/{name},{(time.time()-t0)*1e6:.0f},us_per_call",
              flush=True)
    print(f"_timing/total,{(time.time()-total0)*1e6:.0f},us_per_call")
    _common.close_trace()


if __name__ == "__main__":
    main()
