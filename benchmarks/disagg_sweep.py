"""Colocated-DP vs disaggregated prefill/decode under rising open-loop load.

The serving-level experiment the cluster layer exists for: the registry's
`ds8b-4xh200-colocated` / `ds8b-4xh200-disagg` scenario pair replayed over a
Poisson rate sweep (same trace both modes at each rate). SLO-goodput
(tokens/s inside TTFT+TPOT targets) exhibits the phase-divergence crossover:

  * low rate    — colocated wins: 4 decode-capable engines beat 3, and the
                  migration transfer buys nothing when prefill interference
                  is rare.
  * high rate   — colocated collapses: KV-aware admission queues new
                  requests behind saturated pools (TTFT blows through the
                  SLO — the capacity trap, Obs 1/3), while the disaggregated
                  prefill worker keeps TTFT flat and degrades gracefully in
                  TPOT only.

Also emits per-replica KV-saturation timelines (the Obs 4 claim: the fleet
tail follows the FIRST replica to saturate).
"""
import dataclasses

from repro.scenario import get_scenario

from benchmarks._common import emit, make_cluster

N_REQUESTS = 150
RATES = (1, 2, 4, 8, 12, 16, 20)
MODES = {"colocated": "ds8b-4xh200-colocated",
         "disaggregated": "ds8b-4xh200-disagg"}


def timeline_digest(points, k: int = 8) -> str:
    """Sampled `t:util` pairs — a CSV-safe saturation timeline."""
    if not points:
        return ""
    idx = [int(i * (len(points) - 1) / (k - 1)) for i in range(k)]
    return "|".join(f"{points[i]['t']:.1f}:{points[i]['kv_util']:.2f}"
                    for i in idx)


def run(n_requests: int = N_REQUESTS, rates=RATES, sanitize: bool = False):
    """``sanitize=True`` runs every fleet with the sim sanitizer enabled
    (repro.lint.sanitizer): each step asserts the event-loop invariants the
    benchmark's claims depend on, with bit-identical metrics."""
    base = get_scenario(MODES["colocated"])
    slo = base.slo("interactive")
    scale = (f"n={n_requests};4xH200;sim;"
             f"ttft<{slo.ttft_s};tpot<{slo.tpot_s}")
    rows = []
    goodput = {}
    for rate in rates:
        for mode, name in MODES.items():
            sc = get_scenario(name)
            sc = dataclasses.replace(sc, traffic=dataclasses.replace(
                sc.traffic, rate=float(rate), n_requests=n_requests))
            rt = make_cluster(sc, sanitize=sanitize)
            rt.submit_trace(sc.trace())
            m = rt.run(max_steps=2_000_000)
            s = m.summary(slo)
            rs = m.request_summary()
            assert s["n_finished"] == n_requests, \
                f"{mode}@{rate}: {s['n_finished']}/{n_requests} finished"
            goodput[(mode, rate)] = s["goodput_tok_s"]
            tag = f"{mode}/rate={rate}"
            rows.append(emit(f"disagg_sweep/goodput_tok_s/{tag}",
                             round(s["goodput_tok_s"], 1), scale))
            rows.append(emit(f"disagg_sweep/slo_attainment/{tag}",
                             round(s["slo_attainment"], 3), scale))
            rows.append(emit(f"disagg_sweep/ttft_p95_s/{tag}",
                             round(rs["ttft_s"]["p95"], 4), scale))
            rows.append(emit(f"disagg_sweep/tpot_p95_s/{tag}",
                             round(rs["tpot_s"]["p95"], 5), scale))
            if s["n_migrations"]:
                rows.append(emit(f"disagg_sweep/mean_kv_transfer_s/{tag}",
                                 round(s["mean_transfer_s"], 6), scale))
            first = s["first_saturation_s"]
            rows.append(emit(f"disagg_sweep/first_saturation_s/{tag}",
                             round(first, 2) if first is not None else -1,
                             scale))
            for w in rt.workers:
                rows.append(emit(
                    f"disagg_sweep/kv_timeline/{tag}/worker={w.name}",
                    round(s["workers"][w.name]["peak_kv_util"], 3),
                    timeline_digest(m.saturation_timeline(w))))
    # the phase-divergence crossover: the lowest rate where disaggregation's
    # SLO-goodput overtakes colocated DP
    cross = next((r for r in rates
                  if goodput[("disaggregated", r)]
                  > goodput[("colocated", r)] * 1.01), None)
    rows.append(emit("disagg_sweep/crossover_rate_req_s",
                     cross if cross is not None else -1, scale))
    for r in rates:
        rel = goodput[("disaggregated", r)] / max(goodput[("colocated", r)],
                                                  1e-9)
        rows.append(emit(f"disagg_sweep/goodput_ratio_disagg_over_colo/"
                         f"rate={r}", round(rel, 3), scale))
    return rows


if __name__ == "__main__":
    run()
