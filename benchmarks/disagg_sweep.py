"""Colocated-DP vs disaggregated prefill/decode under rising open-loop load.

The serving-level experiment the cluster layer exists for: a 4xH200 DS-8B
fleet serves a long-context reasoning trace (Poisson arrivals) either as
4 colocated DP replicas or as 1 prefill + 3 decode workers with modeled
KV-transfer migration. SLO-goodput (tokens/s inside TTFT+TPOT targets)
exhibits the phase-divergence crossover:

  * low rate    — colocated wins: 4 decode-capable engines beat 3, and the
                  migration transfer buys nothing when prefill interference
                  is rare.
  * high rate   — colocated collapses: KV-aware admission queues new
                  requests behind saturated pools (TTFT blows through the
                  SLO — the capacity trap, Obs 1/3), while the disaggregated
                  prefill worker keeps TTFT flat and degrades gracefully in
                  TPOT only.

Also emits per-replica KV-saturation timelines (the Obs 4 claim: the fleet
tail follows the FIRST replica to saturate).
"""
from repro.configs.paper_models import DS_DISTILL_8B
from repro.core import perf_model as pm
from repro.core.metrics import SLO
from repro.cluster import (ClusterConfig, ClusterRuntime, PoissonProcess,
                           make_trace, make_sim_worker)
from repro.data.reasoning import LONG_REASONING

from benchmarks._common import emit

N_PAGES = 3000          # 48k KV tokens/worker: saturates at paper-like scale
MAX_SEQS = 64
N_REQUESTS = 150
OSL_CAP = 1200
RATES = (1, 2, 4, 8, 12, 16, 20)
TTFT_SLO_S = 0.5
TPOT_SLO_S = 0.020      # 50 tok/s streaming floor (interactive reasoning)
SCALE = f"n={N_REQUESTS};4xH200;sim;ttft<{TTFT_SLO_S};tpot<{TPOT_SLO_S}"


def build_fleet(mode: str):
    cfg, plan = DS_DISTILL_8B, pm.ParallelismPlan()
    kw = dict(n_pages=N_PAGES, max_seqs=MAX_SEQS)
    if mode == "colocated":
        return [make_sim_worker(cfg, plan, role="colocated", name=f"co{i}",
                                **kw) for i in range(4)]
    ws = [make_sim_worker(cfg, plan, role="prefill", name="pre0", **kw)]
    ws += [make_sim_worker(cfg, plan, role="decode", name=f"dec{i}", **kw)
           for i in range(3)]
    return ws


def timeline_digest(points, k: int = 8) -> str:
    """Sampled `t:util` pairs — a CSV-safe saturation timeline."""
    if not points:
        return ""
    idx = [int(i * (len(points) - 1) / (k - 1)) for i in range(k)]
    return "|".join(f"{points[i]['t']:.1f}:{points[i]['kv_util']:.2f}"
                    for i in idx)


def run(n_requests: int = N_REQUESTS):
    slo = SLO(ttft_s=TTFT_SLO_S, tpot_s=TPOT_SLO_S)
    rows = []
    goodput = {}
    for rate in RATES:
        trace = make_trace(PoissonProcess(rate=rate), LONG_REASONING,
                           n_requests, seed=42, osl_cap=OSL_CAP)
        for mode in ("colocated", "disaggregated"):
            rt = ClusterRuntime(build_fleet(mode), ClusterConfig())
            rt.submit_trace(trace)
            m = rt.run(max_steps=2_000_000)
            s = m.summary(slo)
            rs = m.request_summary()
            assert s["n_finished"] == n_requests, \
                f"{mode}@{rate}: {s['n_finished']}/{n_requests} finished"
            goodput[(mode, rate)] = s["goodput_tok_s"]
            tag = f"{mode}/rate={rate}"
            rows.append(emit(f"disagg_sweep/goodput_tok_s/{tag}",
                             round(s["goodput_tok_s"], 1), SCALE))
            rows.append(emit(f"disagg_sweep/slo_attainment/{tag}",
                             round(s["slo_attainment"], 3), SCALE))
            rows.append(emit(f"disagg_sweep/ttft_p95_s/{tag}",
                             round(rs["ttft_s"]["p95"], 4), SCALE))
            rows.append(emit(f"disagg_sweep/tpot_p95_s/{tag}",
                             round(rs["tpot_s"]["p95"], 5), SCALE))
            if s["n_migrations"]:
                rows.append(emit(f"disagg_sweep/mean_kv_transfer_s/{tag}",
                                 round(s["mean_transfer_s"], 6), SCALE))
            first = s["first_saturation_s"]
            rows.append(emit(f"disagg_sweep/first_saturation_s/{tag}",
                             round(first, 2) if first is not None else -1,
                             SCALE))
            for w in rt.workers:
                rows.append(emit(
                    f"disagg_sweep/kv_timeline/{tag}/worker={w.name}",
                    round(s["workers"][w.name]["peak_kv_util"], 3),
                    timeline_digest(m.saturation_timeline(w))))
    # the phase-divergence crossover: the lowest rate where disaggregation's
    # SLO-goodput overtakes colocated DP
    cross = next((r for r in RATES
                  if goodput[("disaggregated", r)]
                  > goodput[("colocated", r)] * 1.01), None)
    rows.append(emit("disagg_sweep/crossover_rate_req_s",
                     cross if cross is not None else -1, SCALE))
    for r in RATES:
        rel = goodput[("disaggregated", r)] / max(goodput[("colocated", r)],
                                                  1e-9)
        rows.append(emit(f"disagg_sweep/goodput_ratio_disagg_over_colo/"
                         f"rate={r}", round(rel, 3), SCALE))
    return rows


if __name__ == "__main__":
    run()
