"""Multi-tenant SLO tiers under rising load: class-aware vs class-blind.

The fleet-level latency-vs-throughput tier trade-off ("A Systematic
Characterization of LLM Inference on GPUs": interactive and batch tiers
occupy different points on the latency-throughput frontier): the registry's
`ds8b-4xh200-mixed` scenario replays one interactive+batch trace through the
same 4-replica fleet twice per rate —

  * class-aware  — interactive requests jump waiting queues, draw on a
                   reserved KV headroom slice, and are routed/dispatched
                   latency-averse; batch absorbs the backpressure first.
  * class-blind  — identical trace, targets and fleet, but every class at
                   priority 0 and no headroom slice (the baseline where one
                   tier starves the other as load rises).

The claim this benchmark reproduces: class-aware scheduling holds interactive
SLO attainment at-or-above the blind baseline at EVERY load point while total
fleet goodput stays within 10% — interactive latency is bought with batch
queueing delay, not with fleet throughput.

Accounting is the corrected kind for both variants: duration is the fleet
makespan the runtime stamps (not the finished-only window), and
submitted-but-unfinished requests count as SLO misses.
"""
import dataclasses

from repro.scenario import get_scenario

from benchmarks._common import emit, make_cluster

N_REQUESTS = 150
RATES = (2, 4, 8, 12, 16)
SCENARIO = "ds8b-4xh200-mixed"


def class_blind(sc):
    """The same scenario with tier semantics disabled: identical SLO targets
    (measurement unchanged), zero priorities and no KV slice (scheduling
    undifferentiated). The trace tagging depends only on the traffic spec,
    so both variants replay identical per-request tiers."""
    slos = tuple(dataclasses.replace(c, priority=0) for c in sc.slos)
    return dataclasses.replace(sc, name=sc.name + "-blind", slos=slos,
                               class_kv_headroom=0.0)


def run(n_requests: int = N_REQUESTS, rates=RATES):
    base = get_scenario(SCENARIO)
    slos = base.slo_map()
    inter, batch = base.slos[0], base.slos[1]
    mix = dict(base.traffic.class_mix)
    scale = (f"n={n_requests};4xH200;sim;mix=interactive:{mix['interactive']}"
             f"/batch:{mix['batch']};ttft<{inter.ttft_s};tpot<{inter.tpot_s};"
             f"batch ttft<{batch.ttft_s};tpot<{batch.tpot_s}")
    rows = []
    results = {}
    for rate in rates:
        sc_rate = dataclasses.replace(base, traffic=dataclasses.replace(
            base.traffic, rate=float(rate), n_requests=n_requests))
        for label, sc in (("aware", sc_rate), ("blind", class_blind(sc_rate))):
            rt = make_cluster(sc)
            rt.submit_trace(sc.trace())
            m = rt.run(max_steps=4_000_000)
            # corrected accounting: runtime-stamped makespan denominator,
            # unfinished submissions counted as misses
            s = m.summary(slos=slos)
            assert s["n_submitted"] == n_requests, \
                f"{label}@{rate}: {s['n_submitted']}/{n_requests} submitted"
            results[(label, rate)] = s
            tag = f"{label}/rate={rate}"
            for cname, c in s["classes"].items():
                rows.append(emit(
                    f"slo_tiers/{cname}_attainment/{tag}",
                    round(c["slo_attainment"], 3), scale))
                rows.append(emit(
                    f"slo_tiers/{cname}_goodput_tok_s/{tag}",
                    round(c["goodput_tok_s"], 1), scale))
            rows.append(emit(f"slo_tiers/fleet_goodput_tok_s/{tag}",
                             round(s["goodput_tok_s"], 1), scale))
            rows.append(emit(f"slo_tiers/fleet_throughput_tok_s/{tag}",
                             round(s["throughput_tok_s"], 1), scale))
            rows.append(emit(f"slo_tiers/n_unfinished/{tag}",
                             s["n_unfinished"], scale))
    # the tier claim, point by point: interactive attainment held >= blind
    # at every rate, fleet goodput within 10% of the blind baseline
    for rate in rates:
        aw, bl = results[("aware", rate)], results[("blind", rate)]
        d_att = (aw["classes"]["interactive"]["slo_attainment"]
                 - bl["classes"]["interactive"]["slo_attainment"])
        rows.append(emit(
            f"slo_tiers/interactive_attainment_delta_aware_minus_blind/"
            f"rate={rate}", round(d_att, 3), scale))
        rel = aw["goodput_tok_s"] / max(bl["goodput_tok_s"], 1e-9)
        rows.append(emit(f"slo_tiers/fleet_goodput_ratio_aware_over_blind/"
                         f"rate={rate}", round(rel, 3), scale))
    held = all(
        results[("aware", r)]["classes"]["interactive"]["slo_attainment"]
        >= results[("blind", r)]["classes"]["interactive"]["slo_attainment"]
        - 1e-9
        for r in rates)
    within = all(
        results[("aware", r)]["goodput_tok_s"]
        >= 0.9 * results[("blind", r)]["goodput_tok_s"]
        for r in rates)
    rows.append(emit("slo_tiers/interactive_held_every_rate", int(held),
                     scale))
    rows.append(emit("slo_tiers/fleet_goodput_within_10pct", int(within),
                     scale))
    return rows


if __name__ == "__main__":
    run()
