"""Decode→decode rebalancing: migrate ahead of the preemption storm.

Routing is feedforward — it places a request once, on predicted lengths —
so reasoning-length variance concentrates KV pressure on whichever decode
worker drew the long tail (paper Obs 4: one storming worker sets the fleet
tail). The registry's `ds8b-4xh200-rebalance` scenario replays one
past-the-knee Poisson trace through the same 1-prefill + 3-decode fleet
twice:

  * off — routing only: the pressured worker preempts, requeues, and
          re-prefills its own victims (the storm runs its course locally).
  * on  — a `kv_pressure` RebalancePolicy ticks on read-only FleetView
          snapshots; when a decode worker crosses `kv_high` while a peer
          could adopt a victim and keep `dst_headroom` free, the victim is
          ejected and shipped over the same modeled KV-transfer path
          disaggregation uses, *before* the allocator forces a preemption.

Claims asserted (the numbers this benchmark exists to defend):

  1. rebalancing fired (>= 1 `rebalance` event) — the scenario actually
     pressures a worker past `kv_high`;
  2. strictly fewer preemptions than the routing-only fleet (storm energy
     converted into planned migrations);
  3. interactive SLO attainment at least matches the routing-only fleet
     (a migration pauses its victim for one KV transfer — cheaper than the
     requeue + re-prefill it prevents);
  4. an enabled-but-inert hook (victim floor no request can meet) is
     bit-identical to `rebalance=None`: decisions are made on frozen
     views, so until one actuates, the rebalancing event loop IS the
     plain event loop.

Accounting: preemption counts sum over workers; unfinished submissions
count as SLO misses; rebalance migrations ride the same `n_migrations`
accounting as disaggregated prefill→decode handoffs.
"""
import dataclasses

from repro.scenario import get_scenario
from repro.scenario.compile import trace as scenario_trace

from benchmarks._common import emit, make_cluster

SCENARIO = "ds8b-4xh200-rebalance"
N_REQUESTS = 150


def _run_cluster(sc, sanitize: bool = False):
    rt = make_cluster(sc, sanitize=sanitize)
    rt.events.enable_recording()
    rt.submit_trace(scenario_trace(sc))
    m = rt.run(max_steps=4_000_000)
    s = m.summary(slo=sc.slo_map() or sc.slo())
    s["_preemptions"] = sum(w["preemptions"] for w in s["workers"].values())
    s["_n_rebalances"] = sum(1 for e in rt.events.events
                             if e.kind == "rebalance")
    return rt, s


def run(n_requests: int = N_REQUESTS, sanitize: bool = False):
    base = get_scenario(SCENARIO)
    base = dataclasses.replace(base, traffic=dataclasses.replace(
        base.traffic, n_requests=n_requests))
    rb = base.rebalance
    scale = (f"n={n_requests};rate={base.traffic.rate};sim;"
             f"policy={rb.policy};kv_high={rb.kv_high}")

    variants = {
        "on": base,
        "off": dataclasses.replace(base, rebalance=None),
    }
    rows, results = [], {}
    for label, sc in variants.items():
        _, s = _run_cluster(sc, sanitize=sanitize)
        results[label] = s
        assert s["n_submitted"] == n_requests, \
            f"{label}: {s['n_submitted']}/{n_requests} submitted"
        rows.append(emit(f"rebalance/preemptions/{label}",
                         s["_preemptions"], scale))
        rows.append(emit(f"rebalance/slo_attainment/{label}",
                         round(s["slo_attainment"], 3), scale))
        rows.append(emit(f"rebalance/goodput_tok_s/{label}",
                         round(s["goodput_tok_s"], 1), scale))
    on, off = results["on"], results["off"]
    rows.append(emit("rebalance/n_rebalances", on["_n_rebalances"], scale))

    # claim 1: the pressure trigger actually fired
    assert on["_n_rebalances"] >= 1, \
        "no rebalance events — the scenario never pressured a decode " \
        "worker past kv_high"

    # claim 2: strictly fewer preemptions than routing-only
    assert on["_preemptions"] < off["_preemptions"], \
        f"rebalanced fleet preempted {on['_preemptions']}x vs " \
        f"{off['_preemptions']}x routing-only — migrations did not " \
        f"relieve the storm"

    # claim 3: attainment at least matches routing-only
    assert on["slo_attainment"] >= off["slo_attainment"], \
        f"rebalanced attainment {on['slo_attainment']:.3f} below " \
        f"routing-only {off['slo_attainment']:.3f}"

    # claim 4: inert-hook identity — a victim floor no request can meet
    # means decide() never returns a decision; frozen-view observation is
    # read-only, so the run must match rebalance=None bit for bit
    inert = dataclasses.replace(
        base, name=base.name + "-inert",
        rebalance=dataclasses.replace(base.rebalance, min_remaining=10 ** 6))
    _, s_inert = _run_cluster(inert)
    for k in ("_preemptions", "_n_rebalances"):
        s_inert.pop(k), off.pop(k)
    identical = s_inert == off
    rows.append(emit("rebalance/inert_hook_bit_identical", int(identical),
                     scale))
    assert identical, "inert rebalance hook diverged from rebalance=None"
    return rows


if __name__ == "__main__":
    run()
