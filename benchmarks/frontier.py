"""Fig 10 + Obs 6 — frontier dense-vs-sparse divergence: 405B wants TP8 (PP8
catastrophic); R1-671B (MoE+MLA, fp8 weights) wants hybrid PP."""
from repro.configs.paper_models import DEEPSEEK_R1_671B
from repro.configs.registry import get_config
from repro.core import perf_model as pm, planner

from benchmarks._common import emit


def run():
    rows = []
    wl = planner.Workload()
    l405 = get_config("llama3-405b")
    lab405 = {e.label(): e for e in planner.plan(l405, pm.H200, 8, wl)}
    for k in ("TP=8", "PP=8", "TP=4+PP=2", "TP=2+PP=4"):
        e = lab405[k]
        rows.append(emit(f"frontier/405b/completion_s/{k}",
                         round(e.completion_s, 0) if e.feasible else "INF",
                         "paper: TP8=986s, PP8=7537s (7.6x)"))
    rows.append(emit("frontier/405b/pp8_over_tp8",
                     round(lab405["PP=8"].completion_s
                           / lab405["TP=8"].completion_s, 2),
                     "paper 7.6x"))

    r1 = DEEPSEEK_R1_671B
    labr1 = {e.label(): e
             for e in planner.plan(r1, pm.H200, 8, wl, dtype_bytes=1)}
    for k in ("TP=8", "TP=2+PP=4", "TP=4+PP=2", "PP=8"):
        e = labr1[k]
        rows.append(emit(f"frontier/r1/completion_s/{k}",
                         round(e.completion_s, 0) if e.feasible else "INF",
                         "paper: PP4+TP2=1663s < TP8=2047s"))
    rows.append(emit("frontier/r1/tp8_over_hybrid",
                     round(labr1["TP=8"].completion_s
                           / min(labr1["TP=2+PP=4"].completion_s,
                                 labr1["TP=4+PP=2"].completion_s), 2),
                     "paper 1.23x"))
    # the MLA anomaly (Fig 11c): R1 KV/token vs dense peers
    rows.append(emit("frontier/kv_per_token_bytes/405b",
                     l405.kv_bytes_per_token(2), "dense GQA"))
    rows.append(emit("frontier/kv_per_token_bytes/r1",
                     r1.kv_bytes_per_token(2), "MLA latent: ~9x smaller"))
    return rows


if __name__ == "__main__":
    run()
