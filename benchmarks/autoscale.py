"""Elastic autoscaling under diurnal load: cold-start-aware controller vs
static provisioning.

The paper's fleet-sizing discussion prices deployments in devices; a static
fleet must hold the PEAK replica count all day, so off-peak it strands
worker-seconds (Capacity-Bound fleets pay for KV pools nobody is filling).
The registry's `ds8b-autoscale-diurnal` scenario replays one piecewise-rate
trace (trough -> 5x peak -> trough) through three fleets:

  * trough — statically provisioned for the trough (min_workers replicas):
             cheapest, collapses when the peak hits.
  * peak   — statically provisioned for the peak (max_workers replicas):
             holds the SLO everywhere, pays peak worker-seconds all day.
  * auto   — starts at the trough; the slo_guard controller scales on an
             arrival-rate surge (feedforward), KV saturation or attainment
             dips, paying the modeled cold start per minted replica, and
             drains replicas back out after the peak.

Claims asserted (the numbers this benchmark exists to defend):

  1. auto holds SLO attainment within 0.05 of the peak-provisioned fleet;
  2. auto's goodput per provisioned worker-second is >= 1.3x the peak
     fleet's (the utilization gap recovered);
  3. the trough fleet collapses at peak (attainment at least 0.3 below
     peak's — static trough provisioning is not a viable alternative);
  4. with autoscaling disabled — or with a controller whose bounds pin the
     pool (min == max == count) — a constant-rate scenario reproduces the
     fixed-fleet result bit-identically: observation is read-only, so the
     elastic event loop IS the static event loop until the first action.

Accounting: fleet-makespan durations, unfinished submissions count as SLO
misses, and worker-seconds integrate each replica mint -> decommission (cold
start charged, drain charged).
"""
import dataclasses

from repro.scenario import get_scenario
from repro.scenario.compile import trace as scenario_trace

from benchmarks._common import emit, make_cluster

SCENARIO = "ds8b-autoscale-diurnal"
N_REQUESTS = 200
# CI-scale phase schedule: same rates, shorter day. The trough must outlast
# the controller's surge warmup (warmup_ticks * tick_s) or the feedforward
# signal never arms before the peak hits.
SMALL_PHASES = ((12.0, 2.0), (9.0, 10.0), (18.0, 2.0))


def _run_cluster(sc):
    rt = make_cluster(sc)
    rt.submit_trace(scenario_trace(sc))
    m = rt.run(max_steps=4_000_000)
    return rt, m.summary(slo=sc.slo())


def run(n_requests: int = N_REQUESTS, phases=None):
    base = get_scenario(SCENARIO)
    traffic = dataclasses.replace(
        base.traffic, n_requests=n_requests,
        phases=tuple(phases) if phases else base.traffic.phases)
    base = dataclasses.replace(base, traffic=traffic)
    a = base.autoscaler
    scale = (f"n={n_requests};phases={traffic.phases};sim;"
             f"bounds=[{a.min_workers},{a.max_workers}];policy={a.policy}")

    variants = {
        "auto": base,
        "trough": dataclasses.replace(
            base, autoscaler=None,
            fleet=(dataclasses.replace(base.fleet[0],
                                       count=a.min_workers),)),
        "peak": dataclasses.replace(
            base, autoscaler=None,
            fleet=(dataclasses.replace(base.fleet[0],
                                       count=a.max_workers),)),
    }
    rows, results = [], {}
    for label, sc in variants.items():
        rt, s = _run_cluster(sc)
        results[label] = s
        assert s["n_submitted"] == n_requests, \
            f"{label}: {s['n_submitted']}/{n_requests} submitted"
        rows.append(emit(f"autoscale/slo_attainment/{label}",
                         round(s["slo_attainment"], 3), scale))
        rows.append(emit(f"autoscale/goodput_tok_per_worker_s/{label}",
                         round(s["goodput_tok_per_worker_s"], 1), scale))
        rows.append(emit(f"autoscale/worker_seconds/{label}",
                         round(s["worker_seconds"], 1), scale))
        rows.append(emit(f"autoscale/n_scaling_events/{label}",
                         s["n_scaling_events"], scale))
        if label == "auto":
            ups = [e for e in rt.metrics.scaling_events
                   if e.kind == "scale_up"]
            joins = [e for e in rt.metrics.scaling_events if e.kind == "join"]
            peak_pool = max((e.pool_size for e in joins), default=0)
            rows.append(emit("autoscale/peak_pool_size", peak_pool, scale))
            rows.append(emit("autoscale/n_scale_ups", len(ups), scale))
            if ups:
                rows.append(emit("autoscale/first_scale_up_s",
                                 round(ups[0].t, 2), scale))

    auto, peak, trough = (results[k] for k in ("auto", "peak", "trough"))

    # claim 1: attainment within 0.05 of the peak-provisioned fleet
    d_att = peak["slo_attainment"] - auto["slo_attainment"]
    rows.append(emit("autoscale/attainment_delta_peak_minus_auto",
                     round(d_att, 3), scale))
    assert d_att <= 0.05, \
        f"autoscaled attainment {auto['slo_attainment']:.3f} fell more than " \
        f"0.05 below peak-provisioned {peak['slo_attainment']:.3f}"

    # claim 2: >= 1.3x the peak fleet's goodput per worker-second
    ratio = auto["goodput_tok_per_worker_s"] \
        / max(peak["goodput_tok_per_worker_s"], 1e-9)
    rows.append(emit("autoscale/goodput_per_ws_ratio_auto_over_peak",
                     round(ratio, 2), scale))
    assert ratio >= 1.3, \
        f"goodput/worker-second ratio {ratio:.2f} < 1.3x peak-provisioned"

    # claim 3: trough provisioning collapses at peak
    collapse = peak["slo_attainment"] - trough["slo_attainment"]
    rows.append(emit("autoscale/attainment_delta_peak_minus_trough",
                     round(collapse, 3), scale))
    assert collapse >= 0.3, \
        f"trough fleet only {collapse:.3f} below peak — the diurnal swing " \
        f"is too mild to exercise the controller"

    # claim 4: static-path identity — a constant-rate run with autoscaling
    # disabled, and one whose controller bounds pin the pool, match the
    # fixed fleet bit for bit
    flat = dataclasses.replace(
        base, name=base.name + "-flat", autoscaler=None,
        traffic=dataclasses.replace(traffic, process="poisson", rate=4.0,
                                    phases=(), n_requests=min(40, n_requests)))
    pinned = dataclasses.replace(
        flat, name=base.name + "-pinned",
        autoscaler=dataclasses.replace(a, min_workers=base.fleet[0].count,
                                       max_workers=base.fleet[0].count))
    _, s_flat = _run_cluster(flat)
    _, s_pinned = _run_cluster(pinned)
    identical = s_flat == s_pinned
    rows.append(emit("autoscale/static_path_bit_identical", int(identical),
                     scale))
    assert identical, "pinned-bounds controller diverged from the static path"
    return rows


if __name__ == "__main__":
    run()
