"""Fig 3 + Obs 2 — TTFT/TPOT decoupling and E2E convexity: TTFT falls with
concurrency (admission), TPOT rises (bandwidth+capacity dilution); E2E has an
interior sweet spot."""
from repro.configs.paper_models import DS_DISTILL_8B
from repro.core import perf_model as pm

from benchmarks._common import emit, reasoning_requests, run_to_completion, \
    sim_engine


def run(n_requests: int = 400):
    cfg = DS_DISTILL_8B
    plan = pm.ParallelismPlan()
    reqs = reasoning_requests(n_requests, osl_cap=8000, seed=2)
    rows, e2e = [], {}
    sweep = (48, 192, 768, 2048)
    for max_seqs in sweep:
        eng = sim_engine(cfg, plan, max_seqs=max_seqs, admission="naive")
        s = run_to_completion(eng, reqs)
        scale = f"n={n_requests};1xH200;sim"
        rows.append(emit(f"latency/ttft_p50_s/seqs={max_seqs}",
                         round(s["ttft_s"]["p50"], 2), scale))
        rows.append(emit(f"latency/tpot_mean_ms/seqs={max_seqs}",
                         round(s["tpot_s"]["mean"] * 1e3, 2), scale))
        rows.append(emit(f"latency/e2e_p50_s/seqs={max_seqs}",
                         round(s["e2e_s"]["p50"], 2), scale))
        e2e[max_seqs] = s["e2e_s"]["p50"]
    sweet = min(e2e, key=e2e.get)
    rows.append(emit("latency/e2e_sweet_spot_seqs", sweet,
                     "interior optimum = paper's ~2K point (scaled)"))
    return rows


if __name__ == "__main__":
    run()
