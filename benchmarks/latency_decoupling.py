"""Fig 3 + Obs 2 — TTFT/TPOT decoupling and E2E convexity: TTFT falls with
concurrency (admission), TPOT rises (bandwidth+capacity dilution); E2E has an
interior sweet spot."""
import dataclasses

from repro.scenario import ModelRef, Scenario, Traffic, WorkerGroup

from benchmarks._common import emit, run_closed

BASE = Scenario(
    name="latency-decoupling",
    model=ModelRef("ds-distill-8b"),
    fleet=(WorkerGroup(role="colocated", count=1, admission="naive"),),
    traffic=Traffic(process="closed", workload="reasoning",
                    n_requests=400, osl_cap=8000, seed=2))


def run(n_requests: int = 400):
    rows, e2e = [], {}
    for max_seqs in (48, 192, 768, 2048):
        sc = dataclasses.replace(
            BASE, name=f"latency-decoupling-seqs{max_seqs}",
            fleet=(dataclasses.replace(BASE.fleet[0], max_seqs=max_seqs),),
            traffic=dataclasses.replace(BASE.traffic, n_requests=n_requests))
        s = run_closed(sc)
        scale = f"n={n_requests};1xH200;sim"
        rows.append(emit(f"latency/ttft_p50_s/seqs={max_seqs}",
                         round(s["ttft_s"]["p50"], 2), scale))
        rows.append(emit(f"latency/tpot_mean_ms/seqs={max_seqs}",
                         round(s["tpot_s"]["mean"] * 1e3, 2), scale))
        rows.append(emit(f"latency/e2e_p50_s/seqs={max_seqs}",
                         round(s["e2e_s"]["p50"], 2), scale))
        e2e[max_seqs] = s["e2e_s"]["p50"]
    sweet = min(e2e, key=e2e.get)
    rows.append(emit("latency/e2e_sweet_spot_seqs", sweet,
                     "interior optimum = paper's ~2K point (scaled)"))
    return rows


if __name__ == "__main__":
    run()
