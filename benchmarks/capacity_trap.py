"""Fig 2 + Obs 1 — the Capacity Trap: concurrency sweep for DS-8B on one
H200. Throughput rises with concurrency only until KV saturates; past that,
preemption storms collapse it."""
from repro.configs.paper_models import DS_DISTILL_8B
from repro.core import perf_model as pm

from benchmarks._common import emit, reasoning_requests, run_to_completion, \
    sim_engine


def run(n_requests: int = 400):
    cfg = DS_DISTILL_8B
    plan = pm.ParallelismPlan()
    reqs = reasoning_requests(n_requests, osl_cap=8000, seed=1)
    rows = []
    for max_seqs in (64, 256, 1024, 2048):
        eng = sim_engine(cfg, plan, max_seqs=max_seqs, admission="naive")
        s = run_to_completion(eng, reqs)
        scale = f"n={n_requests};1xH200;sim"
        rows.append(emit(f"capacity_trap/tput_tok_s/seqs={max_seqs}",
                         round(s["gen_throughput_tok_s"], 1), scale))
        rows.append(emit(f"capacity_trap/peak_kv_util/seqs={max_seqs}",
                         round(s["peak_kv_util"], 3), scale))
        rows.append(emit(f"capacity_trap/preemptions/seqs={max_seqs}",
                         s["preemptions"], scale))
        rows.append(emit(f"capacity_trap/recomputed_tokens/seqs={max_seqs}",
                         s["recomputed_tokens"], scale))
    return rows


if __name__ == "__main__":
    run()
