"""Fig 2 + Obs 1 — the Capacity Trap: concurrency sweep for DS-8B on one
H200. Throughput rises with concurrency only until KV saturates; past that,
preemption storms collapse it. Each sweep point is the same Scenario with a
different per-replica concurrency cap.

Each point also publishes its ``repro.obs`` regime attribution: the sweep
should read ``compute_bound`` below the knee and flip to ``capacity_bound``
(preemption storms / KV-throttled admission) past it — the trap made
visible as a label, not just a throughput dip."""
import dataclasses

from repro.scenario import ModelRef, Scenario, Traffic, WorkerGroup

from benchmarks._common import emit, run_closed_with_report

BASE = Scenario(
    name="capacity-trap",
    model=ModelRef("ds-distill-8b"),
    fleet=(WorkerGroup(role="colocated", count=1, admission="naive"),),
    traffic=Traffic(process="closed", workload="reasoning",
                    n_requests=400, osl_cap=8000, seed=1))


def run(n_requests: int = 400):
    rows = []
    for max_seqs in (64, 256, 1024, 2048):
        sc = dataclasses.replace(
            BASE, name=f"capacity-trap-seqs{max_seqs}",
            fleet=(dataclasses.replace(BASE.fleet[0], max_seqs=max_seqs),),
            traffic=dataclasses.replace(BASE.traffic, n_requests=n_requests))
        s, rep = run_closed_with_report(sc)
        scale = f"n={n_requests};1xH200;sim"
        rows.append(emit(f"capacity_trap/tput_tok_s/seqs={max_seqs}",
                         round(s["gen_throughput_tok_s"], 1), scale))
        rows.append(emit(f"capacity_trap/peak_kv_util/seqs={max_seqs}",
                         round(s["peak_kv_util"], 3), scale))
        rows.append(emit(f"capacity_trap/preemptions/seqs={max_seqs}",
                         s["preemptions"], scale))
        rows.append(emit(f"capacity_trap/recomputed_tokens/seqs={max_seqs}",
                         s["recomputed_tokens"], scale))
        reg = rep["regimes"]
        rows.append(emit(f"capacity_trap/dominant_regime/seqs={max_seqs}",
                         reg["dominant"], scale))
        rows.append(emit(
            f"capacity_trap/capacity_bound_frac/seqs={max_seqs}",
            round(reg["fractions"]["capacity_bound"], 3), scale))
    return rows


if __name__ == "__main__":
    run()
