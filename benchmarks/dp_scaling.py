"""Fig 6/8 + Obs 4 — DP scaling: near-linear aggregate throughput for 8B;
sub-linear for 32B (per-replica capacity trap bites first). Each point is one
Scenario — a colocated fleet of `dp` replicas fed the same closed-loop
reasoning workload round-robin."""
from repro.scenario import ModelRef, Scenario, Traffic, WorkerGroup

from benchmarks._common import emit, make_cluster


def _fleet_tput(model_name: str, dp: int, n_req: int, seed: int) -> float:
    sc = Scenario(
        name=f"dp-scaling-{model_name}-dp{dp}",
        model=ModelRef(model_name),
        fleet=(WorkerGroup(role="colocated", count=dp, admission="naive"),),
        traffic=Traffic(process="closed", workload="reasoning",
                        n_requests=n_req, osl_cap=2400, seed=seed),
        routing="round_robin")
    rt = make_cluster(sc)
    rt.submit_trace(sc.trace())
    m = rt.run(max_steps=400_000 * dp)
    return m.summary()["throughput_tok_s"]


def run():
    rows = []
    for name, model in (("8b", "ds-distill-8b"), ("32b", "ds-distill-32b")):
        base = None
        for dp in (1, 2, 4, 8):
            t = _fleet_tput(model, dp, n_req=60 * dp, seed=4)
            base = base or t
            rows.append(emit(f"dp_scaling/{name}/tput_tok_s/dp={dp}",
                             round(t, 0), "sim;H200"))
            rows.append(emit(f"dp_scaling/{name}/speedup/dp={dp}",
                             round(t / base, 2),
                             "paper: 8B near-linear; 32B 4.9x@8"))
    return rows


if __name__ == "__main__":
    run()
