"""Fig 6/8 + Obs 4 — DP scaling: near-linear aggregate throughput for 8B;
sub-linear for 32B (per-replica capacity trap bites first)."""
from repro.configs.paper_models import DS_DISTILL_32B, DS_DISTILL_8B
from repro.core import perf_model as pm
from repro.core.router import DPRouter, RouterConfig

from benchmarks._common import emit, reasoning_requests, sim_engine


def _fleet_tput(cfg, dp, n_req, seed):
    plan = pm.ParallelismPlan()
    replicas = [sim_engine(cfg, plan, max_seqs=256, admission="naive")
                for _ in range(dp)]
    router = DPRouter(replicas, RouterConfig(policy="round_robin"))
    cap = replicas[0].alloc.n_pages * 16
    for isl, osl in reasoning_requests(n_req, seed=seed):
        router.submit(int(isl), int(min(osl, cap - isl - 2)), arrival=0.0)
    router.run_all(max_steps=400_000)
    sums = [e.metrics.summary() for e in replicas]
    toks = sum(s["gen_tokens"] for s in sums)
    dur = max(s["duration_s"] for s in sums)
    return toks / dur


def run():
    rows = []
    for name, cfg in (("8b", DS_DISTILL_8B), ("32b", DS_DISTILL_32B)):
        base = None
        for dp in (1, 2, 4, 8):
            t = _fleet_tput(cfg, dp, n_req=60 * dp, seed=4)
            base = base or t
            rows.append(emit(f"dp_scaling/{name}/tput_tok_s/dp={dp}",
                             round(t, 0), "sim;H200"))
            rows.append(emit(f"dp_scaling/{name}/speedup/dp={dp}",
                             round(t / base, 2),
                             "paper: 8B near-linear; 32B 4.9x@8"))
    return rows


if __name__ == "__main__":
    run()
