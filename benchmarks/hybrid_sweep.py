"""Fig 7 + Obs 5 — hybrid-parallelism sweep on a fixed 8-GPU budget for
14B/32B: right-sized TP (DP4xTP2) wins at 32B; DP-dominant wins at 14B."""
from repro.configs.paper_models import DS_DISTILL_14B, DS_DISTILL_32B
from repro.core import perf_model as pm, planner

from benchmarks._common import emit


def run():
    rows = []
    for name, cfg in (("14b", DS_DISTILL_14B), ("32b", DS_DISTILL_32B)):
        ests = planner.plan(cfg, pm.H200, 8)
        for e in ests:
            if e.feasible:
                rows.append(emit(
                    f"hybrid_sweep/{name}/completion_s/{e.label()}",
                    round(e.completion_s, 1),
                    f"conc/replica={e.concurrency}"))
        best = ests[0]
        rows.append(emit(f"hybrid_sweep/{name}/best", best.label(),
                         "paper: 14B->DP8 family, 32B->DP4+TP2"))
    return rows


if __name__ == "__main__":
    run()
