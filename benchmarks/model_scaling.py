"""Fig 11 — parameter scaling on a fixed 8xH200 budget (each model at its
best plan): sublinear throughput degradation; the MLA capacity anomaly."""
from repro.configs.paper_models import (DEEPSEEK_R1_671B, DS_DISTILL_70B,
                                        DS_DISTILL_8B)
from repro.core import perf_model as pm, planner

from benchmarks._common import emit


def run():
    rows = []
    wl = planner.Workload()
    prev_t = prev_n = None
    for name, cfg, db in (("8b", DS_DISTILL_8B, 2),
                          ("70b", DS_DISTILL_70B, 2),
                          ("r1-671b", DEEPSEEK_R1_671B, 1)):
        best = planner.plan(cfg, pm.H200, 8, wl, dtype_bytes=db)[0]
        rows.append(emit(f"model_scaling/{name}/best_plan", best.label(),
                         "paper: DP for 8B, TP for 70B, hybrid for R1"))
        rows.append(emit(f"model_scaling/{name}/decode_tput_tok_s",
                         round(best.decode_tput_tok_s, 0), "8xH200"))
        mem = best.step_parts.get("memory", 0.0)
        tot = max(sum(best.step_parts.values()), 1e-9)
        rows.append(emit(f"model_scaling/{name}/hbm_bound_frac",
                         round(mem / tot, 2),
                         "paper Fig 11b: 8B ~85% HBM-bound, 671B ~50-60%"))
        if prev_t is not None:
            ratio_n = cfg.param_count() / prev_n
            ratio_t = prev_t / best.decode_tput_tok_s
            rows.append(emit(f"model_scaling/{name}/tput_drop_vs_param_ratio",
                             f"{ratio_t:.1f}x_per_{ratio_n:.1f}x",
                             "sublinear degradation (Fig 11a)"))
        prev_t, prev_n = best.decode_tput_tok_s, cfg.param_count()
        rows.append(emit(f"model_scaling/{name}/kv_capacity_tokens",
                         best.kv_capacity_tokens,
                         "MLA anomaly: R1 >> 70B despite 10x params"))
    return rows


if __name__ == "__main__":
    run()
