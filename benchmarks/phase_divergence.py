"""Fig 12/13 + Obs 7 — prefill vs decode resource divergence, from the
analytical model (H200) AND measured from the compiled dry-run artifacts
(v5e): prefill compute-bound, decode memory-bound; arithmetic intensity
collapse. The model/hardware/plan point comes from one resolved Scenario."""
import glob
import json

from repro.core import perf_model as pm
from repro.scenario import ModelRef, Scenario, WorkerGroup, resolve

from benchmarks._common import emit

SC = Scenario(
    name="phase-divergence",
    model=ModelRef("ds-distill-8b"),
    fleet=(WorkerGroup(role="colocated", count=1),))


def run():
    rows = []
    r = resolve(SC)
    cfg, g = r.model, r.groups[0]
    plan, hw = g.plan, g.hardware
    for toks in (512, 2048, 8192):
        p = pm.prefill_step_time(cfg, toks, plan, hw)
        rows.append(emit(f"phase/prefill/compute_over_memory/toks={toks}",
                         round(p["compute"] / max(p["memory"], 1e-12), 2),
                         "(>1 => compute-bound prefill)"))
    for batch in (32, 128, 512):
        d = pm.decode_step_time(cfg, batch, 3500, plan, hw)
        rows.append(emit(f"phase/decode/memory_over_compute/batch={batch}",
                         round(d["memory"] / max(d["compute"], 1e-12), 1),
                         "(>1 => bandwidth-bound decode)"))
    # arithmetic intensity (FLOPs/byte): prefill reuses weights across tokens
    n, w = cfg.active_param_count(), cfg.param_count() * 2
    rows.append(emit("phase/arith_intensity/prefill_2048",
                     round(2 * n * 2048 / w, 0), "FLOPs per weight-byte"))
    rows.append(emit("phase/arith_intensity/decode_b128",
                     round(2 * n * 128 / (w + 128 * 3500
                                          * cfg.kv_bytes_per_token(2)), 2),
                     "collapse (paper §VI-A)"))

    # measured from the v5e dry-run artifacts (same arch family: llama3.2-3b)
    for shape, kind in (("prefill_32k", "prefill"), ("decode_32k", "decode")):
        f = glob.glob(f"experiments/dryrun/llama3.2-3b__{shape}__single__"
                      f"baseline.json")
        if not f:
            continue
        d = json.load(open(f[0]))
        r = d["roofline"]
        rows.append(emit(f"phase/dryrun_v5e/{kind}/bottleneck",
                         r["bottleneck"], "from compiled HLO (llama3.2-3b)"))
        rows.append(emit(
            f"phase/dryrun_v5e/{kind}/t_compute_over_t_memory",
            round(r["t_compute_s"] / max(r["t_memory_s"], 1e-12), 3),
            "roofline terms"))
    return rows


if __name__ == "__main__":
    run()
