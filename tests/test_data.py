"""Workload generator matches the paper's published Fig-1 statistics."""
from repro.data.reasoning import CHAT, REASONING, profile, sample


def test_reasoning_profile_matches_paper():
    p = profile(REASONING, n=50_000, seed=0)
    # paper §III-B: 77% of prompts 50-150 tokens; few exceed 300;
    # 45% of responses exceed 5000 tokens
    assert 0.70 < p["isl_50_150"] < 0.84
    assert p["isl_gt_300"] < 0.05
    assert 0.38 < p["osl_gt_5000"] < 0.52


def test_chat_profile_is_short():
    p = profile(CHAT, n=20_000, seed=0)
    assert p["osl_gt_5000"] < 0.02
    assert p["mean_osl"] < 800


def test_sample_deterministic():
    assert sample(REASONING, 100, seed=3) == sample(REASONING, 100, seed=3)
    assert sample(REASONING, 100, seed=3) != sample(REASONING, 100, seed=4)
