"""HLO cost-analyzer validation: trip-count multiplication, collective
detection inside scan bodies, dtype-policy byte counting."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo import analyze, parse_hlo, shape_bytes


def test_shape_bytes_policy():
    # float buffers count at the bf16 storage policy (2B); ints at native
    assert shape_bytes("f32[128,128]{1,0}") == 128 * 128 * 2
    assert shape_bytes("bf16[64]") == 128
    assert shape_bytes("s32[10]") == 40
    assert shape_bytes("(f32[4,4], s32[2])") == 32 + 8
    assert shape_bytes("f32[128]", float_bytes=4) == 512


def test_scan_flops_multiplied_by_trip_count():
    def f(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        c, _ = jax.lax.scan(body, x, w)
        return c.sum()

    L, N, D = 6, 16, 64
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
        jax.ShapeDtypeStruct((N, D), jnp.float32)).compile()
    res = analyze(comp.as_text(), 1).summary()
    expected = L * 2 * N * D * D
    assert abs(res["flops"] - expected) / expected < 0.01


def test_nested_scan_flops():
    def f(w, x):
        def outer(c, wg):
            def inner(ci, wl):
                return ci @ wl, None
            c, _ = jax.lax.scan(inner, c, wg)
            return c, None
        c, _ = jax.lax.scan(outer, x, w)
        return c.sum()

    G, P, D, N = 3, 4, 32, 8
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((G, P, D, D), jnp.float32),
        jax.ShapeDtypeStruct((N, D), jnp.float32)).compile()
    res = analyze(comp.as_text(), 1).summary()
    expected = G * P * 2 * N * D * D
    assert abs(res["flops"] - expected) / expected < 0.01


def test_parse_hlo_handles_tuples_and_nested_headers():
    txt = """
HloModule m
%body.1 (arg: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  ROOT %t = (s32[], f32[4,4]) tuple(%a, %b)
}
ENTRY %main.2 (x: f32[4,4]) -> f32[4,4] {
  %x = f32[4,4] parameter(0)
  ROOT %d = f32[4,4] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    comps = parse_hlo(txt)
    assert "body.1" in comps and "main.2" in comps
    res = analyze(txt, 1)
    assert res.flops == 2 * 4 * 4 * 4
