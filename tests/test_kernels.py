"""Pallas kernel validation: interpret-mode execution vs the pure-jnp oracle,
swept over shapes / dtypes / GQA ratios / masking modes (brief deliverable c).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


def _qkv(key, B, Sq, Skv, H, KV, D, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, D), jnp.float32).astype(dtype)
    return q, k, v


FLASH_CASES = [
    # B, Sq, Skv, H, KV, D, window, block_q, block_k
    (1, 128, 128, 4, 4, 64, 0, 64, 64),        # MHA, square
    (2, 128, 128, 8, 2, 32, 0, 32, 64),        # GQA 4:1
    (2, 64, 256, 4, 4, 64, 0, 64, 64),         # kv longer than q (chunked ctx)
    (1, 256, 256, 6, 2, 128, 0, 128, 128),     # MXU-aligned D
    (2, 128, 128, 4, 1, 64, 0, 64, 32),        # MQA
    (1, 256, 256, 4, 4, 64, 64, 64, 64),       # sliding window
    (1, 192, 192, 4, 2, 64, 32, 64, 64),       # window + ragged tiles
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(case, dtype):
    B, Sq, Skv, H, KV, D, window, bq, bk = case
    q, k, v = _qkv(jax.random.PRNGKey(hash(case) % 2**31), B, Sq, Skv, H, KV,
                   D, dtype)
    lens = jnp.asarray([Skv] + [max(Skv // 2, 1)] * (B - 1), jnp.int32)
    out = flash_attention(q, k, v, lens, causal=True, window=window,
                          block_q=bq, block_k=bk)
    ref = flash_attention_ref(q, k, v, lens, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_flash_jnp():
    """The dry-run jnp path and the kernel agree (same blocking semantics)."""
    from repro.models.attention import flash_prefill
    B, S, H, KV, D = 2, 128, 8, 4, 64
    q, k, v = _qkv(jax.random.PRNGKey(7), B, S, S, H, KV, D, jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    out_jnp = flash_prefill(q, k, v, q_positions=pos, block_k=64)
    out_kernel = flash_attention(q, k, v, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(out_jnp), np.asarray(out_kernel),
                               rtol=2e-3, atol=2e-3)


PAGED_CASES = [
    # B, KV, G, D, page, P, nblk
    (2, 2, 4, 64, 16, 16, 4),
    (3, 4, 1, 64, 16, 32, 6),       # MHA-style
    (1, 1, 8, 128, 16, 8, 8),       # MQA, deep table
    (4, 2, 2, 32, 16, 64, 3),
]


@pytest.mark.parametrize("case", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_vs_ref(case, dtype):
    B, KV, G, D, page, P, nblk = case
    key = jax.random.PRNGKey(hash(case) % 2**31)
    ks = jax.random.split(key, 4)
    H = KV * G
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (P, page, KV, D), jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (P, page, KV, D), jnp.float32).astype(dtype)
    tables = jax.random.randint(ks[3], (B, nblk), 0, P)
    lens = jnp.asarray([(nblk * page) - 1] + [page // 2] * (B - 1), jnp.int32)
    out = paged_attention(q, kp, vp, tables, lens)
    ref = paged_attention_ref(q.reshape(B, KV, G, D), kp, vp, tables,
                              lens).reshape(B, H, D)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_paged_matches_dense_decode():
    """Paged kernel == the model's dense ring-buffer decode attention."""
    from repro.models.attention import decode_attention
    B, KV, G, D, page, nblk = 2, 2, 2, 32, 16, 4
    H, S = KV * G, 16 * 4
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    kc = jax.random.normal(ks[1], (B, S, KV, D))
    vc = jax.random.normal(ks[2], (B, S, KV, D))
    lens = jnp.asarray([S - 1, 20], jnp.int32)
    dense = decode_attention(q, kc, vc, lens)
    # identity page layout: page i of batch b -> pool page b*nblk+i
    kp = kc.reshape(B * nblk, page, KV, D)
    vp = vc.reshape(B * nblk, page, KV, D)
    tables = jnp.arange(B * nblk, dtype=jnp.int32).reshape(B, nblk)
    paged = paged_attention(q[:, 0], kp, vp, tables, lens).reshape(B, 1, H, D)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(paged),
                               rtol=2e-3, atol=2e-3)
