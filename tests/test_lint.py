"""repro.lint: per-rule fire/clean fixture pairs, suppression parsing, the
CLI, the repo's own src/ staying lint-clean, Scenario.check() feasibility
diagnostics (registry sweep included), and the sim sanitizer — invariant
detection plus metrics bit-identity of sanitize=True runs."""
import dataclasses
import json

import pytest

import repro
from repro.lint import (default_rules, lint_paths, lint_source,
                        SanitizerError)
from repro.lint.__main__ import main as lint_main
from repro.lint.sanitizer import ClusterSanitizer, EngineSanitizer
from repro.scenario import SCENARIOS, Diagnostic, get_scenario, variant

# findings are path-scoped for some rules: fixtures pretend to live in core
SIM_PATH = "repro/core/fixture.py"
# determinism scope (DET_PATHS) adds launch/ + obs/ on top of the sim core;
# analysis/ stays outside every path-gated rule
LAUNCH_PATH = "repro/launch/fixture.py"
OTHER_PATH = "repro/analysis/fixture.py"


def _ids(source, path=SIM_PATH):
    return [f.rule_id for f in lint_source(source, path)]


# --------------------------------------------------------------- rule pairs
def test_rep001_fires_on_global_and_unseeded_rng():
    fires = (
        "import numpy as np\nx = np.random.normal(0, 1)\n",
        "import numpy as np\nnp.random.seed(0)\n",
        "import numpy as np\nrng = np.random.default_rng()\n",
        "import random\nx = random.random()\n",
    )
    for src in fires:
        assert "REP001" in _ids(src), src


def test_rep001_clean_on_seeded_generator_and_outside_det_paths():
    clean = "import numpy as np\nrng = np.random.default_rng(42)\n" \
            "x = rng.normal(0, 1)\n"
    assert "REP001" not in _ids(clean)
    # scope gate: analysis code may use whatever RNG it likes
    fires = "import numpy as np\nx = np.random.normal(0, 1)\n"
    assert "REP001" not in _ids(fires, path=OTHER_PATH)


def test_determinism_scope_covers_launch_and_obs():
    """The lint-PR follow-on: sweep enumeration (launch/) and trace folds
    (obs/) must be as replay-deterministic as the sim core — REP001/REP003
    now gate them. Engine-internal rules (REP006) stay sim-scoped."""
    rng = "import numpy as np\nx = np.random.normal(0, 1)\n"
    setiter = "for x in set(items):\n    pass\n"
    timeq = "ok = t_end == horizon\n"
    for path in (LAUNCH_PATH, "repro/obs/fixture.py"):
        assert "REP001" in _ids(rng, path=path), path
        assert "REP003" in _ids(setiter, path=path), path
        assert "REP006" not in _ids(timeq, path=path), path
    assert "REP006" in _ids(timeq)          # still fires in the sim core


def test_rep002_fires_on_wall_clock_everywhere():
    for src in ("import time\nt = time.time()\n",
                "import time\nt = time.monotonic()\n",
                "from datetime import datetime\nd = datetime.now()\n"):
        assert "REP002" in _ids(src, path=OTHER_PATH), src


def test_rep002_clean_on_virtual_clock():
    assert "REP002" not in _ids("t = engine.now\n")


def test_rep003_fires_on_set_iteration():
    for src in ("for x in {1, 2, 3}:\n    pass\n",
                "for x in set(items):\n    pass\n",
                "ys = [f(x) for x in {1, 2}]\n"):
        assert "REP003" in _ids(src), src


def test_rep003_clean_on_sorted_and_lists():
    for src in ("for x in sorted({1, 2, 3}):\n    pass\n",
                "for x in [1, 2, 3]:\n    pass\n"):
        assert "REP003" not in _ids(src), src


def test_rep004_fires_on_id_as_key():
    assert "REP004" in _ids("key = id(engine) & 0xffff\n")


def test_rep004_clean_on_counter_identity():
    src = "import itertools\nseq = itertools.count()\nkey = next(seq)\n"
    assert "REP004" not in _ids(src)


def test_rep005_fires_on_mutable_default():
    for src in ("def f(xs=[]):\n    pass\n",
                "def f(m={}):\n    pass\n",
                "def f(*, xs=list()):\n    pass\n"):
        assert "REP005" in _ids(src), src


def test_rep005_clean_on_none_default():
    assert "REP005" not in _ids("def f(xs=None):\n    xs = xs or []\n")


def test_rep006_fires_on_time_equality():
    for src in ("if a.t_finished == b.t_finished:\n    pass\n",
                "if now != deadline:\n    pass\n"):
        assert "REP006" in _ids(src), src


def test_rep006_clean_on_tolerance_and_none():
    for src in ("if t_retire is None:\n    pass\n",
                "if abs(now - deadline) < 1e-9:\n    pass\n",
                "if count == 3:\n    pass\n"):
        assert "REP006" not in _ids(src), src


ROUTING_BASE = (
    "from typing import List\n"
    "class RoutingPolicy:\n"
    "    def pick(self, views: List[WorkerView], prompt_len: int,\n"
    "             max_new: int, urgency: float = 0.0) -> int:\n"
    "        raise NotImplementedError\n")


def test_rep007_fires_on_signature_drift():
    drifted = ROUTING_BASE + (
        "class Mine(RoutingPolicy):\n"
        "    def pick(self, views, prompt_len, max_new, urgency=0.0):\n"
        "        return 0\n")
    assert "REP007" in _ids(drifted, path=OTHER_PATH)


def test_rep007_fires_on_rebalance_contract_drift():
    drifted = (
        "class RebalancePolicy:\n"
        "    def decide(self, fleet):\n"
        "        raise NotImplementedError\n")
    assert "REP007" in _ids(drifted, path=OTHER_PATH)


def test_rep007_clean_on_exact_conformance():
    conforming = ROUTING_BASE + (
        "class Mine(RoutingPolicy):\n"
        "    def pick(self, views: List[WorkerView], prompt_len: int,\n"
        "             max_new: int, urgency: float = 0.0) -> int:\n"
        "        return 0\n")
    assert "REP007" not in _ids(conforming, path=OTHER_PATH)


FROZEN = ("import dataclasses\n"
          "@dataclasses.dataclass(frozen=True)\n"
          "class Spec:\n"
          "    x: int = 0\n")


def test_rep008_fires_on_mutation_outside_post_init():
    src = FROZEN + "s = Spec()\nobject.__setattr__(s, 'x', 1)\n"
    assert "REP008" in _ids(src, path=OTHER_PATH)


def test_rep008_clean_inside_post_init():
    src = ("import dataclasses\n"
           "@dataclasses.dataclass(frozen=True)\n"
           "class Spec:\n"
           "    x: int = 0\n"
           "    def __post_init__(self):\n"
           "        object.__setattr__(self, 'x', abs(self.x))\n")
    assert "REP008" not in _ids(src, path=OTHER_PATH)


def test_rep009_fires_on_direct_metrics_mutation():
    fires = (
        "eng.metrics.finish(req, t=1.0)\n",
        "self.metrics.on_event(ev)\n",
        "rt.metrics.note_migration(rec)\n",
        "note_scaling(t, 'join', w)\n",          # any receiver
        "eng.metrics.preemption_events.append(2.0)\n",
        "eng.metrics.t_end = 5.0\n",
        "eng.metrics.n_steps += 1\n",
    )
    for src in fires:
        assert "REP009" in _ids(src), src


def test_rep009_clean_on_reads_and_consumer_modules():
    clean = (
        "s = eng.metrics.summary()\n",
        "x = eng.metrics.t_end\n",
        "log.subscribe(self.metrics.on_event)\n",   # subscription, not call
        "self.metrics = MetricsLog()\n",            # wiring the consumer
    )
    for src in clean:
        assert "REP009" not in _ids(src), src
    # the two stream-consumer modules are the one legal mutation site
    mut = "self.finished.append(ev.ref)\nself.metrics.on_event(ev)\n"
    assert "REP009" not in _ids(mut, path="repro/core/metrics.py")
    assert "REP009" not in _ids(mut, path="repro/cluster/metrics.py")
    # and launch-side scripts are outside REP009's scope entirely
    assert "REP009" not in _ids("eng.metrics.finish(r, t=0)\n",
                                path=LAUNCH_PATH)


def test_rep010_fires_on_engine_access_in_decision_modules():
    fires = (
        "def pick(views):\n    return views[0].engine.alloc.free_pages\n",
        "cap = w.engine.alloc.n_pages * w.engine.alloc.page_size\n",
        "q = len(w.engine.sched.waiting)\n",
    )
    for path in ("repro/cluster/policies.py", "repro/cluster/rebalance.py",
                 "repro/cluster/autoscale.py"):
        for src in fires:
            assert "REP010" in _ids(src, path=path), (path, src)


def test_rep010_clean_on_views_and_out_of_scope_modules():
    clean = (
        "head = v.predicted_headroom_pages() - v.candidate_pages(p, m)\n",
        "ok = v.kv_util >= 0.9 and v.n_waiting > 0\n",
        "pool = fleet.pool('decode')\n",
    )
    for src in clean:
        assert "REP010" not in _ids(src, path="repro/cluster/policies.py"), \
            src
    # the view builder and the runtime are the legal engine readers
    raw = "kv = w.engine.alloc.utilization()\n"
    assert "REP010" not in _ids(raw, path="repro/cluster/view.py")
    assert "REP010" not in _ids(raw, path="repro/cluster/runtime.py")


# ------------------------------------------------------------- suppressions
def test_suppression_with_reason_silences_finding():
    src = "import time\nt = time.time()  # lint: disable=REP002 (measuring)\n"
    assert _ids(src, path=OTHER_PATH) == []


def test_own_line_suppression_governs_next_code_line():
    src = ("import time\n"
           "# lint: disable=REP002 (measuring real wall time here)\n"
           "# (a longer explanation may follow the pragma)\n"
           "t = time.time()\n")
    assert _ids(src, path=OTHER_PATH) == []


def test_suppression_without_reason_is_rep000():
    src = "import time\nt = time.time()  # lint: disable=REP002\n"
    ids = _ids(src, path=OTHER_PATH)
    assert "REP000" in ids and "REP002" in ids


def test_suppression_only_silences_named_rule():
    src = ("import time\n"
           "t = time.time()  # lint: disable=REP001 (wrong rule named)\n")
    assert "REP002" in _ids(src, path=OTHER_PATH)


# ---------------------------------------------------------------------- CLI
def test_cli_exits_nonzero_on_findings(tmp_path, capsys):
    bad = tmp_path / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\nt = time.time()\n")
    assert lint_main([str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "REP002" in out and "1 error(s)" in out


def test_cli_exits_zero_on_clean_tree(tmp_path, capsys):
    good = tmp_path / "repro" / "core" / "good.py"
    good.parent.mkdir(parents=True)
    good.write_text("x = 1\n")
    assert lint_main([str(tmp_path)]) == 0


def test_cli_json_output(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("def f(xs=[]):\n    pass\n")
    assert lint_main(["--json", str(bad)]) == 1
    rows = json.loads(capsys.readouterr().out)
    assert rows and rows[0]["rule_id"] == "REP005"


def test_repo_src_is_lint_clean():
    """The acceptance gate, as a regression test: the repo's own source has
    zero findings (every legitimate pattern carries a justified
    suppression)."""
    src_root = next(iter(repro.__path__))
    findings = lint_paths([src_root])
    assert findings == [], "\n".join(f.format() for f in findings)


# ---------------------------------------------------------- Scenario.check()
def test_registry_sweep_no_diagnostics():
    for name, sc in SCENARIOS.items():
        diags = sc.check()
        assert diags == [], (name, [d.format() for d in diags])


def test_r1_pp_imbalance_is_a_warning():
    """61 layers on pp=4 is legal (one stage is deeper) but worth
    surfacing: errors-only check passes, include_warnings names it."""
    sc = get_scenario("r1-8xh200-pp4tp2")
    assert sc.check() == []
    codes = [d.code for d in sc.check(include_warnings=True)]
    assert "pp_imbalance" in codes


def test_check_kv_pool_too_small():
    sc = variant("ds8b-4xh200-colocated",
                 fleet=(dataclasses.replace(
                     SCENARIOS["ds8b-4xh200-colocated"].fleet[0],
                     n_pages=64),))
    codes = [d.code for d in sc.check()]
    assert "kv_pool_too_small" in codes
    d = next(x for x in sc.check() if x.code == "kv_pool_too_small")
    assert isinstance(d, Diagnostic) and d.severity == "error"
    assert "fleet[0]" in d.field


def test_check_tp_not_dividing_heads():
    sc = variant("ds8b-8xh200-dp8",
                 fleet=(dataclasses.replace(
                     SCENARIOS["ds8b-8xh200-dp8"].fleet[0],
                     plan=dataclasses.replace(
                         SCENARIOS["ds8b-8xh200-dp8"].fleet[0].plan, tp=3)),))
    codes = [d.code for d in sc.check()]
    assert "tp_heads" in codes or "tp_kv_heads" in codes


def test_check_pp_exceeding_layers_is_error():
    base = SCENARIOS["ds8b-8xh200-dp8"]
    sc = variant("ds8b-8xh200-dp8",
                 fleet=(dataclasses.replace(
                     base.fleet[0],
                     plan=dataclasses.replace(base.fleet[0].plan, pp=64)),))
    assert "pp_layers" in [d.code for d in sc.check()]


def test_check_class_mix_sum():
    """The constructor validates names but not weights summing to 1 —
    that's check()'s job (a 90/20 split silently skews the trace)."""
    base = SCENARIOS["ds8b-4xh200-mixed"]
    sc = variant("ds8b-4xh200-mixed",
                 traffic=dataclasses.replace(
                     base.traffic,
                     class_mix=(("interactive", 0.9), ("batch", 0.2))))
    assert "class_mix_sum" in [d.code for d in sc.check()]


def test_check_autoscaler_bounds_on_corrupted_spec():
    """The constructor raises on bad bounds; check() re-validates without
    raising so a post-construction corruption still gets a diagnostic."""
    sc = get_scenario("ds8b-autoscale-diurnal")
    bad = dataclasses.replace(sc)
    object.__setattr__(  # lint: disable=REP008 (test corrupts a spec on purpose)
        bad, "autoscaler",
        dataclasses.replace(sc.autoscaler, min_workers=4, max_workers=6))
    assert "autoscaler_bounds" in [d.code for d in bad.check()]


def test_check_piecewise_phases_on_corrupted_spec():
    sc = get_scenario("ds8b-autoscale-diurnal")
    bad_traffic = dataclasses.replace(sc.traffic)
    object.__setattr__(  # lint: disable=REP008 (test corrupts a spec on purpose)
        bad_traffic, "phases", ())
    bad = dataclasses.replace(sc)
    object.__setattr__(  # lint: disable=REP008 (test corrupts a spec on purpose)
        bad, "traffic", bad_traffic)
    assert "phases_empty" in [d.code for d in bad.check()]


# -------------------------------------------------------------- sim sanitizer
def _small(name, n_requests):
    sc = get_scenario(name)
    return variant(name, traffic=dataclasses.replace(
        sc.traffic, n_requests=n_requests))


def test_sanitized_cluster_run_is_bit_identical():
    """sanitize=True must be observation-only: identical summary dict,
    including a disaggregated fleet (eject/inject paths exercised)."""
    for name in ("ds8b-4xh200-colocated", "ds8b-4xh200-disagg"):
        sc = _small(name, 25)
        plain = sc.to_cluster().run().summary(slo=sc.slo())
        checked = sc.to_cluster(sanitize=True).run().summary(slo=sc.slo())
        assert json.dumps(plain, sort_keys=True) \
            == json.dumps(checked, sort_keys=True), name


def test_sanitized_autoscale_run_is_bit_identical():
    """Minted/retired workers are covered lazily and checked without
    perturbing the controller's decisions."""
    sc = _small("ds8b-autoscale-diurnal", 40)
    plain = sc.to_cluster().run().summary(slo=sc.slo())
    checked = sc.to_cluster(sanitize=True).run().summary(slo=sc.slo())
    assert json.dumps(plain, sort_keys=True) \
        == json.dumps(checked, sort_keys=True)


def test_sanitized_engine_run_matches_default():
    sc = _small("ds8b-4xh200-colocated", 20)
    plain = sc.to_engine()
    checked = sc.to_engine(sanitize=True)
    for eng in (plain, checked):
        for isl, osl in [(512, 64)] * 10:
            eng.submit(isl, osl)
        eng.run()
    assert json.dumps(plain.metrics.summary(), sort_keys=True) \
        == json.dumps(checked.metrics.summary(), sort_keys=True)


def test_sanitizer_catches_kv_leak():
    sc = _small("ds8b-4xh200-colocated", 5)
    eng = sc.to_engine(sanitize=True)
    eng.submit(256, 32)
    assert eng.step()
    eng.alloc._free.pop()            # simulate a leaked page
    with pytest.raises(SanitizerError, match="KV page leak"):
        eng.step()


def test_sanitizer_catches_clock_regression():
    sc = _small("ds8b-4xh200-colocated", 5)
    eng = sc.to_engine(sanitize=True)
    eng.submit(256, 32)
    assert eng.step()
    eng.now = -1.0
    with pytest.raises(SanitizerError, match="clock moved backwards"):
        eng.step()


def test_sanitizer_catches_orphaned_page_table():
    sc = _small("ds8b-4xh200-colocated", 5)
    eng = sc.to_engine(sanitize=True)
    eng.submit(256, 32)
    assert eng.step()
    eng.alloc._tables[99999] = [eng.alloc._free.pop()]  # phantom request
    with pytest.raises(SanitizerError, match="non-running"):
        eng.step()


def test_sanitizer_catches_submitted_log_hole():
    sc = _small("ds8b-4xh200-colocated", 5)
    eng = sc.to_engine(sanitize=True)
    req = eng.submit(256, 32)
    assert eng.step()
    eng.metrics.submitted.remove(req)   # queued but unlogged
    with pytest.raises(SanitizerError, match="submitted log"):
        eng.step()


def test_cluster_sanitizer_catches_lifecycle_violation():
    sc = _small("ds8b-4xh200-colocated", 5)
    rt = sc.to_cluster(sanitize=True)
    rt.workers[0].t_join = 10.0      # active before minted
    rt.workers[0].t_active = 0.0
    rt.submit(256, 32, arrival=0.0)
    with pytest.raises(SanitizerError, match="before joining"):
        rt.run()


def test_cluster_sanitizer_direct_check_passes_on_healthy_fleet():
    sc = _small("ds8b-4xh200-disagg", 10)
    rt = sc.to_cluster()
    for isl, osl in [(512, 64)] * 6:
        rt.submit(isl, osl, arrival=0.0)
    rt.run()
    ClusterSanitizer().check(rt)     # a drained healthy fleet has no findings
    for w in rt.workers:
        EngineSanitizer(w.engine).check()
