import os
import sys

# tests run on the single real CPU device; the 512-device dry-run is executed
# only via repro.launch.dryrun (see EXPERIMENTS.md §Dry-run)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
