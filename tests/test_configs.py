"""Config arithmetic vs published numbers (incl. the paper's own KV table)."""
import pytest

from repro.configs.paper_models import (DEEPSEEK_R1_671B, DS_DISTILL_32B,
                                        DS_DISTILL_70B, DS_DISTILL_8B)
from repro.configs.registry import (ALL_MODELS, ARCHS, SHAPES, cells,
                                    get_config, get_smoke_config)


def test_all_archs_present():
    assert len(ARCHS) == 10
    assert len(SHAPES) == 4


def test_cell_grid():
    allc = list(cells(include_skipped=True))
    assert len(allc) == 40
    runnable = [c for c in allc if c[2] is None]
    assert len(runnable) == 33          # 7 long_500k skips (full-attention)
    skipped = {(a, s) for a, s, r in allc if r}
    assert all(s == "long_500k" for _, s in skipped)
    assert ("h2o-danube-3-4b", "long_500k") not in skipped     # SWA runs
    assert ("zamba2-2.7b", "long_500k") not in skipped
    assert ("xlstm-350m", "long_500k") not in skipped


def test_paper_kv_per_token():
    # §III-C: 32B ≈ 262 KB/token, 70B ≈ 328 KB/token (FP16)
    assert DS_DISTILL_32B.kv_bytes_per_token(2) == 262144
    assert DS_DISTILL_70B.kv_bytes_per_token(2) == 327680
    # MLA compresses R1's cache to (kv_rank + rope) per layer
    assert DEEPSEEK_R1_671B.kv_bytes_per_token(2) == (512 + 64) * 61 * 2


def test_param_counts():
    assert abs(DS_DISTILL_8B.param_count() / 1e9 - 8.0) < 0.2
    assert abs(get_config("llama3-405b").param_count() / 1e9 - 405.9) < 3
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert abs(phi.param_count() / 1e9 - 42) < 1
    assert abs(phi.active_param_count() / 1e9 - 6.6) < 0.3
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.param_count() / 1e9 > 1000
    assert abs(kimi.active_param_count() / 1e9 - 33.7) < 2


def test_state_bytes_attention_free():
    x = get_config("xlstm-350m")
    assert x.kv_bytes_per_token() == 0
    assert x.state_bytes_per_seq() > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_configs_reduce(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 128 and cfg.vocab <= 512
    full = get_config(arch)
    assert cfg.family == full.family
    assert (cfg.moe is None) == (full.moe is None)
    assert (cfg.ssm is None) == (full.ssm is None)
