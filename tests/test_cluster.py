"""Cluster runtime: open-loop arrival gating, KV-transfer model,
colocated-vs-disaggregated equivalence, routing policies, SLO accounting."""
import pytest

from repro.configs.paper_models import DS_DISTILL_8B
from repro.core import perf_model as pm
from repro.core.engine import EngineConfig, InferenceEngine
from repro.core.metrics import SLO, goodput_tok_s, slo_attainment
from repro.core.request import Request
from repro.core.runner import SimRunner
from repro.cluster import (ClusterConfig, ClusterRuntime, GammaProcess,
                           MemoryAware, PoissonProcess, TraceProcess,
                           make_trace, make_sim_worker)
from repro.data.reasoning import REASONING

CFG = DS_DISTILL_8B
PLAN = pm.ParallelismPlan()


def _workers(mode, n=4, n_pages=3000, max_seqs=64):
    if mode == "colocated":
        return [make_sim_worker(CFG, PLAN, role="colocated", name=f"co{i}",
                                n_pages=n_pages, max_seqs=max_seqs)
                for i in range(n)]
    ws = [make_sim_worker(CFG, PLAN, role="prefill", name="pre0",
                          n_pages=n_pages, max_seqs=max_seqs)]
    ws += [make_sim_worker(CFG, PLAN, role="decode", name=f"dec{i}",
                           n_pages=n_pages, max_seqs=max_seqs)
           for i in range(n - 1)]
    return ws


# ------------------------------------------------------------ arrival gating
@pytest.mark.parametrize("mode", ["colocated", "disaggregated"])
@pytest.mark.parametrize("policy", ["round_robin", "jsq", "memory_aware"])
def test_open_loop_arrival_gating(mode, policy):
    """No request is admitted before its arrival, under any policy/mode."""
    rt = ClusterRuntime(_workers(mode), ClusterConfig(policy=policy))
    trace = make_trace(PoissonProcess(rate=20.0), REASONING, 40, seed=3,
                       osl_cap=300)
    rt.submit_trace(trace)
    m = rt.run()
    reqs = m.finished_requests()
    assert len(reqs) == 40
    for r in reqs:
        assert r.t_admitted is not None
        assert r.t_admitted >= r.arrival - 1e-12, \
            f"req {r.rid} admitted at {r.t_admitted} before {r.arrival}"


def test_engine_level_gating_standalone():
    """A single engine holds future-arrival requests invisible to the
    scheduler and fast-forwards its idle clock to the next arrival."""
    eng = InferenceEngine(
        CFG, EngineConfig(n_pages=500, max_num_seqs=8),
        SimRunner(CFG, PLAN, pm.H200))
    r_future = eng.submit(100, 20, arrival=5.0)
    assert not eng.sched.has_work          # gated: scheduler can't see it
    assert eng.has_work
    eng.run()
    assert r_future.t_admitted >= 5.0
    assert r_future.t_finished > 5.0


def test_arrival_processes_monotone_and_rate():
    for proc in (PoissonProcess(rate=4.0), GammaProcess(rate=4.0, cv=2.0),
                 TraceProcess(arrivals=[0.1 * i for i in range(200)])):
        ts = proc.times(200, seed=1)
        assert all(b >= a for a, b in zip(ts, ts[1:]))
    ts = PoissonProcess(rate=4.0).times(2000, seed=0)
    mean_gap = ts[-1] / len(ts)
    assert abs(mean_gap - 0.25) / 0.25 < 0.1


# ------------------------------------------------------------ transfer model
def test_kv_transfer_time_monotone_in_context():
    prev = 0.0
    for ctx in (128, 512, 2048, 8192, 32768):
        t = pm.kv_transfer_time(CFG, ctx, pm.H200)
        assert t > prev
        prev = t


def test_kv_transfer_uses_inter_bw_and_alpha():
    slow = pm.Hardware(name="slow", flops=1e12, hbm_bw=1e12, hbm_cap=80e9,
                       link_bw=400e9, link_alpha=1e-6, inter_bw=10e9)
    fast = pm.Hardware(name="fast", flops=1e12, hbm_bw=1e12, hbm_cap=80e9,
                       link_bw=400e9, link_alpha=1e-6, inter_bw=100e9)
    assert pm.kv_transfer_time(CFG, 4096, slow) > \
        pm.kv_transfer_time(CFG, 4096, fast)
    # alpha floor: even a 1-token transfer pays the handshake
    assert pm.kv_transfer_time(CFG, 1, fast) >= fast.link_alpha


def test_kv_bytes_accounts_state_per_seq():
    from repro.configs.registry import get_config
    hybrid = get_config("zamba2-2.7b")
    one_seq = pm.kv_bytes(hybrid, 1000, n_seqs=1)
    four_seq = pm.kv_bytes(hybrid, 1000, n_seqs=4)
    assert four_seq - one_seq == 3 * hybrid.state_bytes_per_seq(2)
    assert four_seq > one_seq > 0


# --------------------------------------------------- colocated vs disagg
def test_colocated_and_disaggregated_complete_consistently():
    """Both modes finish every request with identical total token counts."""
    trace = make_trace(PoissonProcess(rate=3.0), REASONING, 50, seed=7,
                       osl_cap=600)
    results = {}
    for mode in ("colocated", "disaggregated"):
        rt = ClusterRuntime(_workers(mode), ClusterConfig())
        rt.submit_trace(trace)
        s = rt.run().summary()
        results[mode] = s
    co, dis = results["colocated"], results["disaggregated"]
    assert co["n_finished"] == dis["n_finished"] == 50
    assert co["gen_tokens"] == dis["gen_tokens"]
    assert dis["n_migrations"] == 50           # every request migrated once
    assert dis["mean_transfer_s"] > 0.0
    assert co["n_migrations"] == 0


def test_disaggregated_decode_workers_never_prefill_new_requests():
    """Prefill happens on the prefill pool; decode workers only adopt
    migrated prefill-complete requests (recompute-after-preemption aside)."""
    ws = _workers("disaggregated")
    rt = ClusterRuntime(ws, ClusterConfig())
    trace = make_trace(PoissonProcess(rate=5.0), REASONING, 30, seed=9,
                       osl_cap=400)
    rt.submit_trace(trace)
    m = rt.run()
    pre = next(w for w in ws if w.role == "prefill")
    # every request was admitted (first token) on the prefill worker, and
    # none finished there
    assert len(pre.engine.metrics.finished) == 0
    decode_finished = sum(len(w.engine.metrics.finished)
                          for w in ws if w.role == "decode")
    assert decode_finished == 30
    for rec in m.migrations:
        assert rec.src == pre.name
        assert rec.t_ready > rec.t_eject       # transfer takes positive time
        assert rec.t_delivered >= rec.t_ready  # causality at the adopter


def test_migrated_timestamps_monotone():
    rt = ClusterRuntime(_workers("disaggregated"), ClusterConfig())
    rt.submit_trace(make_trace(PoissonProcess(rate=10.0), REASONING, 25,
                               seed=11, osl_cap=300))
    m = rt.run()
    for r in m.finished_requests():
        assert r.arrival <= r.t_admitted <= r.t_first_token <= r.t_finished
        if r.decode_times:
            assert min(r.decode_times) >= r.t_first_token


# ------------------------------------------------------------------ policies
def test_memory_aware_straggler_penalty_is_scalar():
    """Regression (old tuple-key bug): a slow replica with EQUAL headroom
    must be avoided — the straggler term must influence the score even when
    headrooms differ slightly in its favour."""
    ws = _workers("colocated", n=2)
    pol = MemoryAware(straggler_penalty=2.0, ewma_alpha=0.2)
    # equal headroom; replica 0 is 5x slower per step
    for _ in range(20):
        pol.note_step(0, 0.050)
        pol.note_step(1, 0.010)
    assert pol.pick(ws, 100, 400) == 1
    # and the penalty folds into ONE scalar: a slightly fuller fast replica
    # still beats a much slower emptier one
    ws[1].engine.alloc.grow(999, 16 * 40)      # shrink replica 1's headroom
    assert pol.pick(ws, 100, 400) == 1


def test_dispatcher_least_headroom_best_fit():
    from repro.cluster.policies import LeastKVHeadroom
    ws = [make_sim_worker(CFG, PLAN, role="decode", name=f"d{i}",
                          n_pages=50) for i in range(3)]

    def adopt(w, rid, isl, max_new):
        r = Request(rid=rid, prompt=[1] * isl, max_new_tokens=max_new)
        r.prompt_pos = isl
        assert w.engine.inject(r)
    # d0 nearly full (headroom 11 pages), d1 lighter (36), d2 empty (50)
    adopt(ws[0], 1, 600, 10)
    adopt(ws[1], 2, 200, 10)
    cand = Request(rid=77, prompt=[1] * 200, max_new_tokens=100)
    cand.prompt_pos = 200
    cand.generated = 1
    # candidate needs pages_for(200+99+1) = 19 pages: d0 can't fit;
    # best fit among {d1, d2} is the fuller d1
    assert ws[LeastKVHeadroom().pick(ws, cand)].name == "d1"


def test_small_prefill_pool_accepts_long_decode_requests():
    """Regression: validation on a prefill worker must only require the
    PROMPT to fit (requests migrate out after one token) — a fleet with a
    small prefill pool and big decode pool serves long-OSL requests."""
    ws = [make_sim_worker(CFG, PLAN, role="prefill", name="pre",
                          n_pages=500),          # 8k tokens: < isl + osl
          make_sim_worker(CFG, PLAN, role="decode", name="dec",
                          n_pages=3000)]
    rt = ClusterRuntime(ws, ClusterConfig())
    rt.submit(isl=2000, osl=5000, arrival=0.0)   # 7k > prefill pool
    m = rt.run()
    assert m.summary()["n_finished"] == 1
    # but an over-prompt request is still rejected up front
    with pytest.raises(ValueError, match="prefill-pool"):
        rt.submit(isl=9000, osl=100)


def test_cluster_rid_counter_seeded_past_existing_requests():
    """Regression: joining a cluster must not recycle rids an engine already
    issued (rids key the allocator tables; collision corrupts page
    accounting)."""
    w = make_sim_worker(CFG, PLAN, n_pages=2000)
    pre = w.engine.submit(100, 50)               # issues rid 0 pre-cluster
    rt = ClusterRuntime([w], ClusterConfig())
    rt.submit(100, 50, arrival=0.0)
    m = rt.run()
    rids = [r.rid for r in m.finished_requests()]
    assert len(rids) == 2 and len(set(rids)) == 2
    assert pre.rid in rids


# --------------------------------------------------------------- SLO metrics
def test_slo_attainment_and_goodput():
    def mk(ttft, tpot, gen=100):
        r = Request(rid=0, prompt=[1] * 10, max_new_tokens=gen)
        r.arrival, r.t_admitted = 0.0, 0.0
        r.t_first_token = ttft
        r.generated = gen
        r.t_finished = ttft + tpot * (gen - 1)
        return r
    good = mk(0.5, 0.01)
    bad_ttft = mk(5.0, 0.01)
    bad_tpot = mk(0.5, 0.2)
    slo = SLO(ttft_s=1.0, tpot_s=0.05)
    assert slo.attained(good) and not slo.attained(bad_ttft) \
        and not slo.attained(bad_tpot)
    reqs = [good, bad_ttft, bad_tpot]
    assert slo_attainment(reqs, slo) == pytest.approx(1 / 3)
    assert goodput_tok_s(reqs, slo, duration_s=10.0) == pytest.approx(10.0)
    # unconstrained SLO: everything attains
    assert slo_attainment(reqs, SLO()) == 1.0


def test_cluster_saturation_timeline_reported():
    ws = _workers("colocated", n=2, n_pages=600, max_seqs=64)
    rt = ClusterRuntime(ws, ClusterConfig())
    rt.submit_trace(make_trace(PoissonProcess(rate=50.0), REASONING, 40,
                               seed=5, osl_cap=500))
    m = rt.run()
    s = m.summary(SLO(ttft_s=2.0, tpot_s=0.05))
    for w in ws:
        tl = m.saturation_timeline(w)
        assert tl and all(0.0 <= p["kv_util"] <= 1.0 for p in tl)
        assert s["workers"][w.name]["peak_kv_util"] > 0.0
    assert "goodput_tok_s" in s and "slo_attainment" in s
