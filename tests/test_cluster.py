"""Cluster runtime: open-loop arrival gating, KV-transfer model,
colocated-vs-disaggregated equivalence, routing policies, SLO accounting,
multi-tenant SLO classes."""
import pytest

from repro.configs.paper_models import DS_DISTILL_8B
from repro.core import perf_model as pm
from repro.core.engine import EngineConfig, InferenceEngine
from repro.core.metrics import (SLO, goodput_tok_s, latency_stats,
                                slo_attainment)
from repro.core.request import Request
from repro.core.runner import SimRunner
from repro.cluster import (ClusterConfig, ClusterRuntime, GammaProcess,
                           MemoryAware, PoissonProcess, TraceProcess,
                           assign_classes, make_trace, make_sim_worker)
from repro.data.reasoning import REASONING

CFG = DS_DISTILL_8B
PLAN = pm.ParallelismPlan()


def _workers(mode, n=4, n_pages=3000, max_seqs=64):
    if mode == "colocated":
        return [make_sim_worker(CFG, PLAN, role="colocated", name=f"co{i}",
                                n_pages=n_pages, max_seqs=max_seqs)
                for i in range(n)]
    ws = [make_sim_worker(CFG, PLAN, role="prefill", name="pre0",
                          n_pages=n_pages, max_seqs=max_seqs)]
    ws += [make_sim_worker(CFG, PLAN, role="decode", name=f"dec{i}",
                           n_pages=n_pages, max_seqs=max_seqs)
           for i in range(n - 1)]
    return ws


# ------------------------------------------------------------ arrival gating
@pytest.mark.parametrize("mode", ["colocated", "disaggregated"])
@pytest.mark.parametrize("policy", ["round_robin", "jsq", "memory_aware"])
def test_open_loop_arrival_gating(mode, policy):
    """No request is admitted before its arrival, under any policy/mode."""
    rt = ClusterRuntime(_workers(mode), ClusterConfig(policy=policy))
    trace = make_trace(PoissonProcess(rate=20.0), REASONING, 40, seed=3,
                       osl_cap=300)
    rt.submit_trace(trace)
    m = rt.run()
    reqs = m.finished_requests()
    assert len(reqs) == 40
    for r in reqs:
        assert r.t_admitted is not None
        assert r.t_admitted >= r.arrival - 1e-12, \
            f"req {r.rid} admitted at {r.t_admitted} before {r.arrival}"


def test_engine_level_gating_standalone():
    """A single engine holds future-arrival requests invisible to the
    scheduler and fast-forwards its idle clock to the next arrival."""
    eng = InferenceEngine(
        CFG, EngineConfig(n_pages=500, max_num_seqs=8),
        SimRunner(CFG, PLAN, pm.H200))
    r_future = eng.submit(100, 20, arrival=5.0)
    assert not eng.sched.has_work          # gated: scheduler can't see it
    assert eng.has_work
    eng.run()
    assert r_future.t_admitted >= 5.0
    assert r_future.t_finished > 5.0


def test_arrival_processes_monotone_and_rate():
    for proc in (PoissonProcess(rate=4.0), GammaProcess(rate=4.0, cv=2.0),
                 TraceProcess(arrivals=[0.1 * i for i in range(200)])):
        ts = proc.times(200, seed=1)
        assert all(b >= a for a, b in zip(ts, ts[1:]))
    ts = PoissonProcess(rate=4.0).times(2000, seed=0)
    mean_gap = ts[-1] / len(ts)
    assert abs(mean_gap - 0.25) / 0.25 < 0.1


# ------------------------------------------------------------ transfer model
def test_kv_transfer_time_monotone_in_context():
    prev = 0.0
    for ctx in (128, 512, 2048, 8192, 32768):
        t = pm.kv_transfer_time(CFG, ctx, pm.H200)
        assert t > prev
        prev = t


def test_kv_transfer_uses_inter_bw_and_alpha():
    slow = pm.Hardware(name="slow", flops=1e12, hbm_bw=1e12, hbm_cap=80e9,
                       link_bw=400e9, link_alpha=1e-6, inter_bw=10e9)
    fast = pm.Hardware(name="fast", flops=1e12, hbm_bw=1e12, hbm_cap=80e9,
                       link_bw=400e9, link_alpha=1e-6, inter_bw=100e9)
    assert pm.kv_transfer_time(CFG, 4096, slow) > \
        pm.kv_transfer_time(CFG, 4096, fast)
    # alpha floor: even a 1-token transfer pays the handshake
    assert pm.kv_transfer_time(CFG, 1, fast) >= fast.link_alpha


def test_kv_bytes_accounts_state_per_seq():
    from repro.configs.registry import get_config
    hybrid = get_config("zamba2-2.7b")
    one_seq = pm.kv_bytes(hybrid, 1000, n_seqs=1)
    four_seq = pm.kv_bytes(hybrid, 1000, n_seqs=4)
    assert four_seq - one_seq == 3 * hybrid.state_bytes_per_seq(2)
    assert four_seq > one_seq > 0


# --------------------------------------------------- colocated vs disagg
def test_colocated_and_disaggregated_complete_consistently():
    """Both modes finish every request with identical total token counts."""
    trace = make_trace(PoissonProcess(rate=3.0), REASONING, 50, seed=7,
                       osl_cap=600)
    results = {}
    for mode in ("colocated", "disaggregated"):
        rt = ClusterRuntime(_workers(mode), ClusterConfig())
        rt.submit_trace(trace)
        s = rt.run().summary()
        results[mode] = s
    co, dis = results["colocated"], results["disaggregated"]
    assert co["n_finished"] == dis["n_finished"] == 50
    assert co["gen_tokens"] == dis["gen_tokens"]
    assert dis["n_migrations"] == 50           # every request migrated once
    assert dis["mean_transfer_s"] > 0.0
    assert co["n_migrations"] == 0


def test_disaggregated_decode_workers_never_prefill_new_requests():
    """Prefill happens on the prefill pool; decode workers only adopt
    migrated prefill-complete requests (recompute-after-preemption aside)."""
    ws = _workers("disaggregated")
    rt = ClusterRuntime(ws, ClusterConfig())
    trace = make_trace(PoissonProcess(rate=5.0), REASONING, 30, seed=9,
                       osl_cap=400)
    rt.submit_trace(trace)
    m = rt.run()
    pre = next(w for w in ws if w.role == "prefill")
    # every request was admitted (first token) on the prefill worker, and
    # none finished there
    assert len(pre.engine.metrics.finished) == 0
    decode_finished = sum(len(w.engine.metrics.finished)
                          for w in ws if w.role == "decode")
    assert decode_finished == 30
    for rec in m.migrations:
        assert rec.src == pre.name
        assert rec.t_ready > rec.t_eject       # transfer takes positive time
        assert rec.t_delivered >= rec.t_ready  # causality at the adopter
    # per-engine accounting follows the migration: ejected requests leave
    # the prefill log, adopters record them — each engine's submitted set
    # covers exactly what it finished
    assert pre.engine.metrics.submitted == []
    for w in ws:
        if w.role == "decode":
            sub = {r.rid for r in w.engine.metrics.submitted}
            assert {r.rid for r in w.engine.metrics.finished} <= sub


def test_migrated_timestamps_monotone():
    rt = ClusterRuntime(_workers("disaggregated"), ClusterConfig())
    rt.submit_trace(make_trace(PoissonProcess(rate=10.0), REASONING, 25,
                               seed=11, osl_cap=300))
    m = rt.run()
    for r in m.finished_requests():
        assert r.arrival <= r.t_admitted <= r.t_first_token <= r.t_finished
        if r.decode_times:
            assert min(r.decode_times) >= r.t_first_token


# ------------------------------------------------------------------ policies
def test_straggler_warmup_no_spurious_straggle():
    """Regression: the lazily-grown EWMA table held 0.0 for workers that
    never stepped, dragging the fleet mean down — the first active worker was
    charged a straggler penalty at warmup while never-stepped workers got 0.0
    straggle for free. The EWMA now lives in the runtime-owned
    StragglerTracker and reaches policies as ``WorkerView.step_ewma``."""
    from repro.cluster.policies import relative_straggle
    from repro.cluster.view import StragglerTracker, snapshot
    ws = _workers("colocated", n=3)
    tr = StragglerTracker()
    for _ in range(3):
        tr.note_step("co1", 0.010)
    views = [snapshot(w, straggler=tr) for w in ws]
    v = {u.name: u for u in views}
    # the sole observed worker IS the fleet mean: zero straggle, not +1.0
    assert relative_straggle(v["co1"], views) == pytest.approx(0.0)
    # unobserved workers have no data — no reward (was -1.0), no penalty
    assert relative_straggle(v["co0"], views) == 0.0
    assert relative_straggle(v["co2"], views) == 0.0
    # the first observation seeds the EWMA (no bias toward zero at warmup)
    tr2 = StragglerTracker(alpha=0.2)
    tr2.note_step("co0", 0.040)
    assert tr2.get("co0") == pytest.approx(0.040)
    # and warmup must not skew routing: equal-headroom fleet, only worker 0
    # observed — the pick must not avoid (or favour) it for straggle reasons
    tr3 = StragglerTracker()
    tr3.note_step("co0", 0.020)
    views3 = [snapshot(w, straggler=tr3) for w in ws]
    MemoryAware().pick(views3, 100, 400)
    assert relative_straggle(views3[0], views3) == pytest.approx(0.0)


def test_memory_aware_straggler_penalty_is_scalar():
    """Regression (old tuple-key bug): a slow replica with EQUAL headroom
    must be avoided — the straggler term must influence the score even when
    headrooms differ slightly in its favour."""
    from repro.cluster.view import StragglerTracker, snapshot
    ws = _workers("colocated", n=2)
    tr = StragglerTracker(alpha=0.2)
    pol = MemoryAware(straggler_penalty=2.0)
    # equal headroom; replica 0 is 5x slower per step
    for _ in range(20):
        tr.note_step("co0", 0.050)
        tr.note_step("co1", 0.010)
    views = [snapshot(w, straggler=tr) for w in ws]
    assert pol.pick(views, 100, 400) == 1
    # and the penalty folds into ONE scalar: a slightly fuller fast replica
    # still beats a much slower emptier one (fresh views see the grow —
    # decision sites rebuild views per decision)
    ws[1].engine.alloc.grow(999, 16 * 40)      # shrink replica 1's headroom
    views = [snapshot(w, straggler=tr) for w in ws]
    assert pol.pick(views, 100, 400) == 1


def test_straggle_keyed_by_name_survives_pool_mutation():
    """Autoscaling mutates the pool mid-run: a retired worker's latency
    history must not transfer to whichever replica inherits its slot, and
    the fleet mean must be computed over the *current* pool's observed
    members — a long-retired straggler must not drag the reference mean."""
    from repro.cluster.policies import relative_straggle
    from repro.cluster.view import StragglerTracker, snapshot
    ws = _workers("colocated", n=3)
    tr = StragglerTracker()
    for _ in range(5):
        tr.note_step("co0", 0.050)        # straggler
        tr.note_step("co1", 0.010)
        tr.note_step("co2", 0.010)
    views = [snapshot(w, straggler=tr) for w in ws]
    v = {u.name: u for u in views}
    # co0 retires: the current pool's views exclude it — co1/co2 are
    # mutually average
    assert relative_straggle(v["co1"],
                             [v["co1"], v["co2"]]) == pytest.approx(0.0)
    # with co0 in the pool, co1 is faster than the mean (negative straggle)
    assert relative_straggle(v["co1"], views) < 0
    tr.forget("co0")
    assert tr.get("co0") is None
    # a fresh replica reusing the name starts with no history
    fresh = snapshot(ws[0], straggler=tr)
    assert fresh.step_ewma is None
    assert relative_straggle(fresh, [fresh, v["co1"], v["co2"]]) == 0.0


def test_dispatcher_least_headroom_best_fit():
    from repro.cluster.policies import LeastKVHeadroom
    from repro.cluster.view import snapshot
    ws = [make_sim_worker(CFG, PLAN, role="decode", name=f"d{i}",
                          n_pages=50) for i in range(3)]

    def adopt(w, rid, isl, max_new):
        r = Request(rid=rid, prompt=[1] * isl, max_new_tokens=max_new)
        r.prompt_pos = isl
        assert w.engine.inject(r)
    # d0 nearly full (headroom 11 pages), d1 lighter (36), d2 empty (50)
    adopt(ws[0], 1, 600, 10)
    adopt(ws[1], 2, 200, 10)
    cand = Request(rid=77, prompt=[1] * 200, max_new_tokens=100)
    cand.prompt_pos = 200
    cand.generated = 1
    # candidate needs pages_for(200+99+1) = 19 pages: d0 can't fit;
    # best fit among {d1, d2} is the fuller d1
    views = [snapshot(w) for w in ws]
    assert ws[LeastKVHeadroom().pick(views, cand)].name == "d1"


def test_small_prefill_pool_accepts_long_decode_requests():
    """Regression: validation on a prefill worker must only require the
    PROMPT to fit (requests migrate out after one token) — a fleet with a
    small prefill pool and big decode pool serves long-OSL requests."""
    ws = [make_sim_worker(CFG, PLAN, role="prefill", name="pre",
                          n_pages=500),          # 8k tokens: < isl + osl
          make_sim_worker(CFG, PLAN, role="decode", name="dec",
                          n_pages=3000)]
    rt = ClusterRuntime(ws, ClusterConfig())
    rt.submit(isl=2000, osl=5000, arrival=0.0)   # 7k > prefill pool
    m = rt.run()
    assert m.summary()["n_finished"] == 1
    # but an over-prompt request is still rejected up front
    with pytest.raises(ValueError, match="prefill-pool"):
        rt.submit(isl=9000, osl=100)


def test_cluster_rid_counter_seeded_past_existing_requests():
    """Regression: joining a cluster must not recycle rids an engine already
    issued (rids key the allocator tables; collision corrupts page
    accounting)."""
    w = make_sim_worker(CFG, PLAN, n_pages=2000)
    pre = w.engine.submit(100, 50)               # issues rid 0 pre-cluster
    rt = ClusterRuntime([w], ClusterConfig())
    rt.submit(100, 50, arrival=0.0)
    m = rt.run()
    rids = [r.rid for r in m.finished_requests()]
    assert len(rids) == 2 and len(set(rids)) == 2
    assert pre.rid in rids


# --------------------------------------------------------------- SLO metrics
def test_slo_attainment_and_goodput():
    def mk(ttft, tpot, gen=100):
        r = Request(rid=0, prompt=[1] * 10, max_new_tokens=gen)
        r.arrival, r.t_admitted = 0.0, 0.0
        r.t_first_token = ttft
        r.generated = gen
        r.t_finished = ttft + tpot * (gen - 1)
        return r
    good = mk(0.5, 0.01)
    bad_ttft = mk(5.0, 0.01)
    bad_tpot = mk(0.5, 0.2)
    slo = SLO(ttft_s=1.0, tpot_s=0.05)
    assert slo.attained(good) and not slo.attained(bad_ttft) \
        and not slo.attained(bad_tpot)
    reqs = [good, bad_ttft, bad_tpot]
    assert slo_attainment(reqs, slo) == pytest.approx(1 / 3)
    assert goodput_tok_s(reqs, slo, duration_s=10.0) == pytest.approx(10.0)
    # unconstrained SLO: everything attains
    assert slo_attainment(reqs, SLO()) == 1.0


def test_cluster_saturation_timeline_reported():
    ws = _workers("colocated", n=2, n_pages=600, max_seqs=64)
    rt = ClusterRuntime(ws, ClusterConfig())
    rt.submit_trace(make_trace(PoissonProcess(rate=50.0), REASONING, 40,
                               seed=5, osl_cap=500))
    m = rt.run()
    s = m.summary(SLO(ttft_s=2.0, tpot_s=0.05))
    for w in ws:
        tl = m.saturation_timeline(w)
        assert tl and all(0.0 <= p["kv_util"] <= 1.0 for p in tl)
        assert s["workers"][w.name]["peak_kv_util"] > 0.0
    assert "goodput_tok_s" in s and "slo_attainment" in s


# ----------------------------------------------- migration delivery horizon
def test_migration_delivery_respects_pending_fleet_events():
    """Regression: the idle-fast-forward horizon must count events engines
    can't see yet — an unrouted arrival (or an undelivered earlier transfer)
    can spawn a delivery that needs the idle time a later-ready transfer
    would otherwise burn."""
    ws = _workers("disaggregated", n=2)          # pre0 + dec0
    rt = ClusterRuntime(ws, ClusterConfig())
    rt.submit(isl=100, osl=50, arrival=2.0)      # unrouted future arrival
    req = Request(rid=999, prompt=[1] * 200, max_new_tokens=100, arrival=0.0)
    req.prompt_pos = 200
    req.generated = 1
    rt._migrating.append({"req": req, "src": "pre0",
                          "eject": 0.5, "ready": 5.0})
    rt._deliver_migrations()
    dec = next(w for w in ws if w.role == "decode")
    # the t=2.0 arrival is the fleet's next event: dec0 must NOT be
    # fast-forwarded to the t=5.0 transfer completion past it
    assert dec.engine.now == 0.0
    assert len(rt._migrating) == 1
    # and the run still drains: both requests finish
    m = rt.run()
    assert m.summary()["n_finished"] == 2
    for rec in m.migrations:
        assert rec.t_delivered >= rec.t_ready


# ----------------------------------------------------- goodput denominators
def test_unfinished_requests_count_as_slo_misses_with_horizon():
    """Regression: finished-only attainment ignored the worst violators —
    the requests still in flight at the horizon."""
    def mk_finished(ttft, tpot, gen=100):
        r = Request(rid=0, prompt=[1] * 10, max_new_tokens=gen)
        r.arrival, r.t_admitted = 0.0, 0.0
        r.t_first_token = ttft
        r.generated = gen
        r.t_finished = ttft + tpot * (gen - 1)
        return r
    good = mk_finished(0.5, 0.01)
    unfin = Request(rid=1, prompt=[1] * 10, max_new_tokens=100, arrival=1.0)
    unfin.generated = 30                     # in flight at horizon
    slo = SLO(ttft_s=1.0, tpot_s=0.05)
    # legacy (no horizon): finished-only denominator
    assert slo_attainment([good, unfin], slo) == 1.0
    # with a horizon the in-flight request is a miss, not an omission
    assert slo_attainment([good, unfin], slo, horizon=10.0) == 0.5
    # and its tokens are throughput, not goodput
    assert goodput_tok_s([good, unfin], slo, duration_s=10.0) \
        == pytest.approx(10.0)
    # a request finishing AFTER the horizon misses within that window
    assert slo_attainment([good], slo, horizon=1.0) == 0.0   # finishes 1.49
    assert goodput_tok_s([good], slo, duration_s=1.0, horizon=1.0) == 0.0


def test_rejected_submit_leaves_no_phantom_in_accounting():
    """Regression: submit recorded the request before validation could
    reject it, leaving an eternal 'unfinished miss' in horizon accounting."""
    w = make_sim_worker(CFG, PLAN, n_pages=50)
    with pytest.raises(ValueError):
        w.engine.submit(100, 5000)           # exceeds the KV pool
    assert w.engine.metrics.submitted == []


def test_cluster_summary_uses_fleet_makespan_denominator():
    """Regression: duration_s derived from finished requests only shrank the
    goodput denominator while the tail was still being served. The runtime
    stamps its fleet clock; the summary must use it."""
    ws = _workers("colocated", n=2, n_pages=3000, max_seqs=64)
    rt = ClusterRuntime(ws, ClusterConfig())
    trace = make_trace(PoissonProcess(rate=10.0), REASONING, 20, seed=3,
                       osl_cap=400)
    rt.submit_trace(trace)
    m = rt.run()
    s = m.summary(SLO(ttft_s=2.0, tpot_s=0.05))
    makespan = max(w.engine.now for w in ws)
    t0 = min(r.arrival for r in rt.submitted)
    assert m.t_end == pytest.approx(makespan)
    assert s["duration_s"] == pytest.approx(makespan - t0)
    # the fleet clock can only extend past the last finish, never shrink
    last_finish = max(r.t_finished for r in m.finished_requests())
    assert makespan >= last_finish - 1e-9
    assert s["n_submitted"] == 20 and s["n_unfinished"] == 0


# ------------------------------------------------------------ latency stats
def test_latency_stats_percentiles():
    """Regression: even-length p50 took the upper-middle element and p95 used
    int(0.95 n), which lands on the max for n <= 20."""
    st = latency_stats(list(range(1, 21)))           # 1..20
    assert st["p50"] == pytest.approx(10.5)          # true median, not 11
    assert st["p95"] == 19                           # nearest-rank, not 20
    assert st["max"] == 20
    assert st["mean"] == pytest.approx(10.5)
    assert latency_stats([3.0, None, 1.0])["p50"] == pytest.approx(2.0)
    assert latency_stats([7.0])["p95"] == 7.0
    assert latency_stats([]) == {"mean": 0.0, "p50": 0.0, "p95": 0.0,
                                 "max": 0.0}
    # MetricsLog and ClusterMetrics both report through this one helper
    ws = _workers("colocated", n=1, n_pages=3000)
    rt = ClusterRuntime(ws, ClusterConfig())
    for i in range(4):
        rt.submit(100, 50, arrival=0.1 * i)
    m = rt.run()
    fleet = m.request_summary()["ttft_s"]
    engine = ws[0].engine.metrics.summary()["ttft_s"]
    assert fleet == engine


def test_slo_attained_none_measurements_are_symmetric():
    """Regression: ttft=None failed while tpot=None passed. Both are now
    vacuous — an undefined measurement cannot violate a target (single-token
    outputs have no inter-token gap); unfinished-as-miss is the horizon
    accounting's job."""
    r = Request(rid=0, prompt=[1] * 10, max_new_tokens=1)
    r.arrival, r.t_first_token, r.t_finished = 0.0, 0.1, 0.1
    r.generated = 1                              # tpot undefined
    assert SLO(tpot_s=0.001).attained(r)
    assert not SLO(ttft_s=0.05).attained(r)      # defined ttft still misses
    assert SLO(ttft_s=0.2, tpot_s=0.001).attained(r)
    # ttft undefined on a finished request (degenerate): same vacuous rule
    r2 = Request(rid=1, prompt=[1] * 10, max_new_tokens=5)
    r2.arrival, r2.t_finished, r2.generated = 0.0, 1.0, 5
    assert SLO(ttft_s=0.05).attained(r2)
    # unfinished never attains, regardless of targets
    assert not SLO().attained(Request(rid=2, prompt=[1], max_new_tokens=1))


# ------------------------------------------------------- multi-tenant classes
def _mixed_trace(n, rate, seed=13, osl_cap=600):
    trace = make_trace(PoissonProcess(rate=rate), REASONING, n, seed=seed,
                       osl_cap=osl_cap)
    return assign_classes(trace, (("interactive", 0.5), ("batch", 0.5)),
                          seed=seed + 1)


PRIORITIES = {"interactive": 10, "batch": 0}


def test_uniform_priorities_are_class_blind():
    """Contract: empty OR uniform priorities = class-blind. A single-tenant
    scenario whose one class carries a nonzero priority must not flip
    routing/dispatch into the urgent branches (normalised urgency is
    differentiation, not absolute level)."""
    from repro.core.admission import ClassPolicy
    single = ClassPolicy(priority={"interactive": 10})
    assert single.normalized_urgency("interactive") == 0.0
    uniform = ClassPolicy(priority={"gold": 5, "silver": 5})
    assert uniform.normalized_urgency("gold") == 0.0
    tiered = ClassPolicy(priority=PRIORITIES)
    assert tiered.normalized_urgency("interactive") == 1.0
    assert tiered.normalized_urgency("batch") == 0.0
    assert tiered.normalized_urgency("") == 0.0      # untagged = least tier
    assert ClassPolicy().normalized_urgency("anything") == 0.0


def test_interactive_jumps_waiting_queue_but_not_preempted():
    w = make_sim_worker(CFG, PLAN, n_pages=3000, max_seqs=4,
                        class_priorities=PRIORITIES)
    eng = w.engine
    batch = [eng.submit(100, 50, slo_class="batch") for _ in range(6)]
    inter = eng.submit(100, 50, slo_class="interactive")
    waiting = list(eng.sched.waiting)
    # the interactive request sits ahead of every waiting batch request
    assert waiting.index(inter) < min(waiting.index(b) for b in batch
                                      if b in waiting)
    # but a preempted victim still resumes first (forward-progress guard)
    from repro.core.request import State
    victim = waiting[0] if waiting[0] is not inter else waiting[1]
    eng.sched.waiting.remove(victim)
    victim.state = State.PREEMPTED
    eng.sched.waiting.appendleft(victim)
    late = eng.submit(100, 50, slo_class="interactive")
    assert list(eng.sched.waiting)[0] is victim
    assert list(eng.sched.waiting).index(late) \
        < list(eng.sched.waiting).index(batch[-1])


def test_class_victim_selection_prefers_batch():
    w = make_sim_worker(CFG, PLAN, n_pages=3000, max_seqs=8,
                        class_priorities=PRIORITIES)
    sched = w.engine.sched
    old_batch = Request(rid=1, prompt=[1] * 50, max_new_tokens=50,
                        arrival=0.0, slo_class="batch")
    young_inter = Request(rid=2, prompt=[1] * 50, max_new_tokens=50,
                          arrival=1.0, slo_class="interactive")
    for r in (old_batch, young_inter):
        r.prompt_pos = 50
        assert w.engine.inject(r)
    grower = Request(rid=3, prompt=[1] * 50, max_new_tokens=50, arrival=2.0,
                     slo_class="interactive")
    # lowest-urgency class is evicted first even though the interactive
    # request is younger (single-class fleets keep youngest-victim FCFS)
    assert sched._pick_victim(exclude=grower) is old_batch


def test_batch_blocked_from_interactive_kv_slice():
    """KV headroom slice: with the pool predicted-full past (1 - reserve -
    slice), a batch candidate is refused admission while an identical
    interactive candidate still admits."""
    w = make_sim_worker(CFG, PLAN, n_pages=100, max_seqs=16,
                        class_priorities=PRIORITIES, class_kv_headroom=0.2)
    eng = w.engine
    adm = eng.sched.admission
    running = []
    r = Request(rid=1, prompt=[1] * 600, max_new_tokens=600,
                slo_class="batch")
    r.prompt_pos = 600
    assert eng.inject(r)
    running.append(r)
    # running needs 76 pages; candidate adds 13 -> 89 total, which fits the
    # protected budget (95 = (1-reserve)*100) but not the batch budget
    # (75 = (1-reserve-0.2)*100)
    batch_cand = Request(rid=2, prompt=[1] * 100, max_new_tokens=100,
                         slo_class="batch")
    inter_cand = Request(rid=3, prompt=[1] * 100, max_new_tokens=100,
                         slo_class="interactive")
    decided = (adm.admit(batch_cand, running, eng.alloc),
               adm.admit(inter_cand, running, eng.alloc))
    assert decided == (False, True)


def test_interactive_never_starved_and_class_goodput_sums():
    """End-to-end invariants on a loaded mixed-tenancy fleet: every
    interactive request is eventually served (no starvation), the interactive
    tier's p95 TTFT beats batch's, and class-conditional goodput sums to
    fleet goodput."""
    slos = {"interactive": SLO(ttft_s=0.5, tpot_s=0.02),
            "batch": SLO(ttft_s=30.0, tpot_s=0.5)}
    ws = [make_sim_worker(CFG, PLAN, role="colocated", name=f"co{i}",
                          n_pages=1500, max_seqs=32,
                          class_priorities=PRIORITIES, class_kv_headroom=0.1)
          for i in range(2)]
    rt = ClusterRuntime(ws, ClusterConfig(class_priorities=PRIORITIES))
    rt.submit_trace(_mixed_trace(40, rate=25.0))
    m = rt.run()
    s = m.summary(slos=slos)
    assert s["n_finished"] == 40                 # nobody starved
    inter = [r for r in m.finished_requests() if r.slo_class == "interactive"]
    batch = [r for r in m.finished_requests() if r.slo_class == "batch"]
    assert inter and batch
    p95 = lambda rs: latency_stats([r.ttft() for r in rs])["p95"]  # noqa:E731
    assert p95(inter) <= p95(batch)
    total = sum(c["goodput_tok_s"] for c in s["classes"].values())
    assert total == pytest.approx(s["goodput_tok_s"])
    assert {c.slo_class for c in m.finished_requests()} \
        == {"interactive", "batch"}
