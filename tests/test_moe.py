"""MoE dispatch: sort-based capacity semantics + distributed-vs-reference
equivalence (shard_map split and replicated paths)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property-based tests need the 'test' extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import MoEConfig, ModelConfig
from repro.models.moe import (_dispatch_indices, moe_ffn, moe_ffn_reference,
                              router_probs)


def _cfg(E=4, k=2, cf=8.0):
    return ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                       moe=MoEConfig(n_experts=E, top_k=k, d_ff_expert=48,
                                     capacity_factor=cf))


def _params(key, cfg):
    m = cfg.moe
    ks = jax.random.split(key, 4)
    d, f = cfg.d_model, m.d_ff_expert
    return {
        "router": jax.random.normal(ks[0], (d, m.n_experts)) * 0.1,
        "we_gate": jax.random.normal(ks[1], (m.n_experts, d, f)) * 0.1,
        "we_up": jax.random.normal(ks[2], (m.n_experts, d, f)) * 0.1,
        "we_down": jax.random.normal(ks[3], (m.n_experts, f, d)) * 0.1,
    }


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 64), st.integers(2, 8), st.integers(1, 16))
def test_dispatch_indices_properties(n_assign, n_experts, capacity):
    flat = np.random.default_rng(n_assign).integers(0, n_experts, n_assign)
    slot, keep = _dispatch_indices(jnp.asarray(flat, jnp.int32), n_experts,
                                   capacity)
    slot, keep = np.asarray(slot), np.asarray(keep)
    # kept slots are unique and within range
    kept = slot[keep]
    assert len(set(kept.tolist())) == len(kept)
    assert ((kept >= 0) & (kept < n_experts * capacity)).all()
    # per-expert kept count == min(count, capacity)
    for e in range(n_experts):
        n_e = int((flat == e).sum())
        kept_e = int((keep & (slot // capacity == e)).sum())
        assert kept_e == min(n_e, capacity)
    # FCFS within expert: dropped assignments are the later ones
    for e in range(n_experts):
        idxs = np.where(flat == e)[0]
        expected_kept = set(idxs[:capacity].tolist())
        assert set(idxs[keep[idxs]].tolist()) == expected_kept


def test_capacity_drops_reduce_output():
    cfg_tight = _cfg(cf=0.25)
    cfg_loose = _cfg(cf=8.0)
    key = jax.random.PRNGKey(0)
    p = _params(key, cfg_tight)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 32))
    out_tight = moe_ffn_reference(x, p, cfg_tight)
    out_loose = moe_ffn_reference(x, p, cfg_loose)
    # tight capacity must actually drop tokens -> different outputs, with
    # some rows zeroed-contribution
    assert float(jnp.abs(out_tight - out_loose).max()) > 1e-6


def test_replicated_vs_reference_single_device():
    """mesh=1x1 shard_map path must equal the plain reference."""
    from repro.parallel.sharding import ParallelContext, make_test_mesh
    cfg = _cfg(cf=8.0)
    key = jax.random.PRNGKey(0)
    p = _params(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
    ref = moe_ffn_reference(x.reshape(-1, 32), p, cfg).reshape(x.shape)
    mesh = make_test_mesh(1, 1)
    ctx = ParallelContext(mesh=mesh, fsdp_axis=None)
    for mode in ("split", "replicated"):
        ctx2 = ParallelContext(mesh=mesh, fsdp_axis=None, moe_dispatch=mode)
        out = moe_ffn(x, p, cfg, ctx2, token_axes=None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
