"""Checkpoint: atomic save/restore round-trip, retention, async writer."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint as ckpt


def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 8)),
            "nested": {"b": jnp.arange(10), "c": jnp.float32(seed)}}


def test_roundtrip(tmp_path):
    t = _tree(0)
    ckpt.save(t, str(tmp_path), step=5)
    like = jax.tree_util.tree_map(lambda a: jnp.zeros_like(a), t)
    restored, step = ckpt.restore(like, str(tmp_path))
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(_tree(s), str(tmp_path), step=s, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored, _ = ckpt.restore(_tree(0), str(tmp_path), step=4)
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(_tree(4)["a"]))
    steps = sorted(int(p.name.split("-")[1])
                   for p in tmp_path.glob("step-*"))
    assert steps == [4, 5]


def test_async_save(tmp_path):
    t = _tree(7)
    thread = ckpt.save_async(t, str(tmp_path), step=7)
    thread.join(timeout=30)
    restored, step = ckpt.restore(t, str(tmp_path))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]))


def test_structure_mismatch_rejected(tmp_path):
    ckpt.save(_tree(0), str(tmp_path), step=1)
    import pytest
    with pytest.raises(AssertionError):
        ckpt.restore({"different": jnp.zeros((2,))}, str(tmp_path))
