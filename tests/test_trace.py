"""repro.trace: the typed event spine. Event/EventLog semantics, JSONL
round-trip, replay determinism across all three cluster shapes (colocated,
disaggregated, autoscaled), the first-divergence differ (library + CLI),
the benchmark trace/preflight plumbing, and the cross-fidelity crosscheck."""
import dataclasses
import os
import sys

import pytest

from repro.scenario import (SCENARIOS, bounds_for, crosscheck, get_scenario,
                            variant)
from repro.trace import (KINDS, Event, EventLog, diff_events, dump_events,
                         load_events)
from repro.trace.__main__ import main as trace_main

COLOCATED = "ds8b-4xh200-colocated"
DISAGG = "ds8b-4xh200-disagg"
ELASTIC = "ds8b-autoscale-diurnal"


def _shrunk(name, n=14, **changes):
    sc = get_scenario(name)
    return dataclasses.replace(
        sc, traffic=dataclasses.replace(sc.traffic, n_requests=n, **changes))


def _cluster_events(sc, trace=None):
    rt = sc.to_cluster()
    rt.events.enable_recording()
    rt.submit_trace(sc.trace() if trace is None else trace)
    rt.run()
    return rt.events.events


# ------------------------------------------------------------ event basics
def test_event_is_frozen_and_kind_checked():
    ev = Event(t=1.0, kind="arrival", rid=3)
    with pytest.raises(dataclasses.FrozenInstanceError):
        ev.t = 2.0
    with pytest.raises(ValueError, match="unknown event kind"):
        Event(t=0.0, kind="teleport")


def test_event_to_dict_excludes_live_ref():
    sentinel = object()
    ev = Event(t=0.5, kind="finish", rid=1, worker="dec0",
               payload={"osl": 8}, ref=sentinel)
    d = ev.to_dict()
    assert d == {"t": 0.5, "kind": "finish", "rid": 1, "worker": "dec0",
                 "payload": {"osl": 8}}
    # ref is also excluded from equality: same transition, same event
    assert ev == Event(t=0.5, kind="finish", rid=1, worker="dec0",
                       payload={"osl": 8})


def test_eventlog_recording_is_opt_in_subscribers_always_fire():
    log = EventLog()
    seen = []
    log.subscribe(seen.append)
    log.emit(Event(t=0.0, kind="arrival", rid=1))
    assert log.events is None and not log.recording and len(seen) == 1
    log.enable_recording()
    log.emit(Event(t=1.0, kind="finish", rid=1))
    assert [e.kind for e in log.events] == ["finish"] and len(seen) == 2
    log.unsubscribe(seen.append)
    log.emit(Event(t=2.0, kind="run_end"))
    assert len(seen) == 2


# --------------------------------------------------- replay determinism
@pytest.mark.parametrize("name,n", [(COLOCATED, 14), (DISAGG, 14),
                                    (ELASTIC, 40)])
def test_same_scenario_same_seed_is_event_identical(name, n):
    """The headline guarantee: one Scenario + seed, run twice, yields the
    same stream event for event — routing, preemption, migration and
    scaling decisions included, not just the same aggregates."""
    a = _cluster_events(_shrunk(name, n))
    b = _cluster_events(_shrunk(name, n))
    res = diff_events(a, b)
    assert res.identical, res.report()
    assert len(a) > 0
    kinds = {e.kind for e in a}
    assert kinds <= set(KINDS)
    assert "arrival" in kinds and "finish" in kinds


def test_engine_stream_forwards_into_fleet_stream_with_worker_names():
    evs = _cluster_events(_shrunk(COLOCATED))
    named = [e for e in evs if e.kind in ("arrival", "finish", "decode_step")]
    assert named and all(e.worker for e in named)


def test_perturbed_seed_diverges_with_readable_first_divergence():
    base = _shrunk(COLOCATED)
    a = _cluster_events(base)
    pert = dataclasses.replace(
        base, traffic=dataclasses.replace(base.traffic, seed=base.traffic.seed + 1))
    b = _cluster_events(pert)
    res = diff_events(a, b, label_a="seed0", label_b="seed1")
    assert not res.identical and res.index is not None
    report = res.report()
    assert "diverge" in report and "seed0" in report and "seed1" in report
    # the shared prefix really is shared: everything before index matches
    for i in range(res.index):
        assert a[i].to_dict() == b[i].to_dict()


# ----------------------------------------------------- jsonl + differ CLI
def test_jsonl_roundtrip_bit_exact(tmp_path):
    evs = _cluster_events(_shrunk(COLOCATED, 6))
    p1, p2 = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    assert dump_events(evs, p1) == len(evs)
    dump_events(evs, p2)
    assert open(p1, "rb").read() == open(p2, "rb").read()
    assert load_events(p1) == [e.to_dict() for e in evs]


def test_differ_cli_exit_codes(tmp_path, capsys):
    base = _shrunk(COLOCATED, 6)
    evs = _cluster_events(base)
    pert = dataclasses.replace(
        base, traffic=dataclasses.replace(base.traffic, seed=99))
    a = str(tmp_path / "a.jsonl")
    b = str(tmp_path / "b.jsonl")
    c = str(tmp_path / "c.jsonl")
    dump_events(evs, a)
    dump_events(evs, b)
    dump_events(_cluster_events(pert), c)
    assert trace_main(["diff", a, b]) == 0
    assert "identical" in capsys.readouterr().out
    assert trace_main(["diff", a, c]) == 1
    assert "diverge" in capsys.readouterr().out
    assert trace_main(["diff", a, str(tmp_path / "missing.jsonl")]) == 2


# ------------------------------------------- benchmark plumbing + preflight
def _common():
    root = os.path.join(os.path.dirname(__file__), "..")
    if os.path.abspath(root) not in (os.path.abspath(p) for p in sys.path):
        sys.path.insert(0, root)
    from benchmarks import _common as mod
    return mod


def test_benchmark_preflight_exits_nonzero_on_bad_spec(capsys):
    mod = _common()
    bad = variant(COLOCATED, fleet=(dataclasses.replace(
        SCENARIOS[COLOCATED].fleet[0], n_pages=64),))
    with pytest.raises(SystemExit) as exc:
        mod.preflight(bad)
    assert exc.value.code == 2
    assert "kv_pool_too_small" in capsys.readouterr().err
    good = _shrunk(COLOCATED, 6)
    assert mod.preflight(good) is good


def test_benchmark_trace_out_writes_loadable_stream(tmp_path):
    mod = _common()
    out = str(tmp_path / "bench.jsonl")
    mod.set_trace_out(out)
    try:
        rt = mod.make_cluster(_shrunk(COLOCATED, 6))
        rt.submit_trace(_shrunk(COLOCATED, 6).trace())
        rt.run()
    finally:
        mod.set_trace_out(None)
    rows = load_events(out)
    assert rows and {r["kind"] for r in rows} <= set(KINDS)
    assert any(r["kind"] == "run_end" for r in rows)


# ------------------------------------------------------------- crosscheck
def test_crosscheck_passes_on_registry_scenario():
    rep = crosscheck(get_scenario(COLOCATED))
    assert rep.ok, [f.format() for f in rep.findings]
    assert "tput_vs_engine" in rep.ratios
    for metric, (r, cv, rv) in rep.ratios.items():
        lo, hi = bounds_for(COLOCATED)[metric]
        assert lo <= r <= hi


def test_crosscheck_flags_seeded_misconfiguration():
    """One replica with a starved KV pool that still passes the static
    check: each fidelity tolerates it alone, the fidelities disagreeing
    about the same spec is what exposes it."""
    base = SCENARIOS[COLOCATED]
    g = base.fleet[0]
    sc = variant(COLOCATED, routing="round_robin",
                 fleet=(dataclasses.replace(g, count=3),
                        dataclasses.replace(g, count=1, n_pages=459,
                                            admission="naive", prefix="bad")))
    assert sc.check() == []          # statically clean — that's the point
    rep = crosscheck(sc)
    assert not rep.ok
    assert "XCHK001" in [f.rule_id for f in rep.findings]
    assert all(f.severity == "error" for f in rep.findings)


def test_crosscheck_static_failure_is_xchk000():
    bad = variant(COLOCATED, fleet=(dataclasses.replace(
        SCENARIOS[COLOCATED].fleet[0], n_pages=64),))
    rep = crosscheck(bad)
    assert not rep.ok and rep.ratios == {}
    assert [f.rule_id for f in rep.findings] == ["XCHK000"]


def test_bounds_for_merges_per_scenario_overrides():
    merged = bounds_for(DISAGG)
    assert merged["goodput_vs_engine"][0] < \
        bounds_for(COLOCATED)["goodput_vs_engine"][0]
    assert set(merged) == set(bounds_for(COLOCATED))
