"""repro.obs: exact request spans, windowed time-series, regime
classification, the bottleneck report, Perfetto export, the CLI, and the
benchmark ``--report`` wiring. The two load-bearing guarantees:

  * span decompositions sum to measured end-to-end latency *exactly* (ulp
    equality, on every finished request of every cluster shape);
  * attaching obs to a run leaves its metrics byte-identical (pure
    stream consumer, REP009)."""
import dataclasses
import json
import os
import sys
from fractions import Fraction

import pytest

from repro.obs import (PHASES, REGIMES, RegimeRules, WindowStats, attach,
                       attribute, bottleneck_report, build_windows, classify,
                       fold_spans, regime_fractions, render_text,
                       to_chrome_trace)
from repro.obs.__main__ import main as obs_main
from repro.scenario import (ModelRef, Scenario, Traffic, WorkerGroup,
                            get_scenario, requests)
from repro.trace import dump_events

COLOCATED = "ds8b-4xh200-colocated"
DISAGG = "ds8b-4xh200-disagg"
ELASTIC = "ds8b-autoscale-diurnal"


def _shrunk(name, n=14, **changes):
    sc = get_scenario(name)
    return dataclasses.replace(
        sc, traffic=dataclasses.replace(sc.traffic, n_requests=n, **changes))


def _cluster_run(sc):
    rt = sc.to_cluster()
    rt.events.enable_recording()
    rt.submit_trace(sc.trace())
    rt.run()
    return rt


def _finished(events):
    return {e.ref.rid: e.ref for e in events if e.kind == "finish"}


# one engine-fidelity scenario family for seeded regime traces: a closed
# reasoning burst against a configurable pool/cap (the capacity-trap shape)
def _trap(max_seqs, n=40, n_pages=None, cap_tokens=10 ** 9,
          max_steps=400_000):
    fleet = WorkerGroup(role="colocated", count=1, admission="naive",
                        max_seqs=max_seqs,
                        **({"n_pages": n_pages} if n_pages else {}))
    sc = Scenario(name=f"obs-trap-{max_seqs}", model=ModelRef("ds-distill-8b"),
                  fleet=(fleet,),
                  traffic=Traffic(process="closed", workload="reasoning",
                                  n_requests=n, osl_cap=8000, seed=1))
    eng = sc.to_engine()
    eng.events.enable_recording()
    capacity = eng.alloc.n_pages * eng.alloc.page_size
    for isl, osl in requests(sc):
        osl = min(osl, cap_tokens, max(capacity - isl - 2, 1))
        eng.submit(int(isl), int(osl), arrival=0.0)
    eng.run(max_steps=max_steps)
    return eng


# ------------------------------------------------------------ span exactness
@pytest.mark.parametrize("name,n", [(COLOCATED, 20), (DISAGG, 16),
                                    (ELASTIC, 30)])
def test_span_sum_equals_e2e_to_the_last_ulp(name, n):
    """The headline guarantee, on all three cluster shapes: per-phase
    durations telescope exactly — as rationals AND as correctly-rounded
    floats — to the measured end-to-end latency of every finished
    request."""
    rt = _cluster_run(_shrunk(name, n))
    events = rt.events.events
    by_rid = _finished(events)
    fold = fold_spans(events)
    assert len(fold.spans) == len(by_rid) > 0
    for s in fold.spans:
        r = by_rid[s.rid]
        assert s.exact_total == Fraction(r.t_finished) - Fraction(r.arrival)
        assert s.total_s == r.e2e()          # float ==, deliberately
        assert all(f >= 0 for f in s.phase_fracs.values())


def test_disagg_spans_carry_migration_and_kv_transfer():
    events = _cluster_run(_shrunk(DISAGG, 16)).events.events
    fold = fold_spans(events)
    migrated = [s for s in fold.spans if len(s.workers) > 1]
    assert migrated, "disagg run produced no migrated spans"
    for s in migrated:
        assert s.phase_fracs["kv_transfer"] > 0
        # prefill happened on a prefill-role worker, decode on the adopter
        assert s.workers[0] != s.workers[-1]


def test_span_segments_tile_the_request_lifetime():
    events = _cluster_run(_shrunk(COLOCATED, 12)).events.events
    for s in fold_spans(events).spans:
        assert s.segments, s.rid
        assert s.segments[0].t0 == s.arrival
        assert s.segments[-1].t1 == s.t_finished
        for a, b in zip(s.segments, s.segments[1:]):
            assert a.t1 == b.t0              # contiguous, no gaps/overlap
            assert a.t0 < a.t1
        assert {seg.phase for seg in s.segments} <= set(PHASES)


def test_truncated_trace_leaves_open_spans_not_garbage():
    eng = _trap(max_seqs=2048, n=40, n_pages=400, max_steps=4000)
    events = eng.events.events
    fold = fold_spans(events)
    assert fold.open_spans                   # run was cut mid-flight
    rep = bottleneck_report(events)
    assert rep["requests"]["n_unfinished"] == len(fold.open_spans)


# ----------------------------------------------------------------- windows
def test_windows_are_deterministic_across_same_seed_runs():
    a = build_windows(_cluster_run(_shrunk(COLOCATED, 14)).events.events)
    b = build_windows(_cluster_run(_shrunk(COLOCATED, 14)).events.events)
    assert a.workers == b.workers
    assert a.window_s == b.window_s
    for w in a.workers:
        assert a.by_worker[w] == b.by_worker[w]   # dataclass field equality


def test_window_token_counts_are_exact():
    """decode/prefill tokens come from per-step events, not snapshot
    subsampling: window sums must equal the stream's own totals."""
    events = _cluster_run(_shrunk(COLOCATED, 12)).events.events
    ws = build_windows(events)
    decode = sum(len(e.payload["rids"]) for e in events
                 if e.kind == "decode_step")
    prefill = sum(e.payload["chunk"] for e in events if e.kind == "prefill")
    assert sum(w.decode_tokens for w in ws.all_windows()) == decode
    assert sum(w.prefill_tokens for w in ws.all_windows()) == prefill


def test_windows_see_migration_traffic_on_the_destination():
    events = _cluster_run(_shrunk(DISAGG, 16)).events.events
    ws = build_windows(events)
    n_inject = sum(1 for e in events if e.kind == "inject")
    assert sum(w.migrations_in for w in ws.all_windows()) == n_inject
    assert sum(w.migrations_out for w in ws.all_windows()) == n_inject
    assert any(w.transfer_overlap_s > 0 for w in ws.all_windows())


def test_step_payload_feeds_windows_without_engine_access():
    """The PR-9 step-payload extension: absolute KV page counts and the
    live cap are in the stream, so windows get them post-hoc."""
    events = _cluster_run(_shrunk(COLOCATED, 8)).events.events
    steps = [e for e in events if e.kind == "step"]
    assert steps
    for e in steps:
        assert {"kv_pages_used", "kv_pages_free", "max_seqs"} <= \
            set(e.payload)
    ws = build_windows(events)
    assert any(w.kv_pages_used_max > 0 for w in ws.all_windows())
    assert all(w.max_seqs > 0 for w in ws.all_windows() if w.n_samples)


# ----------------------------------------------------------------- regimes
def _w(**kw):
    base = dict(worker="w0", t0=0.0, t1=1.0)
    base.update(kw)
    return WindowStats(**base)


def test_classify_decision_table():
    r = RegimeRules()
    assert classify(_w(warming=True), r) == ("comms_bound", "cold_start")
    assert classify(_w(), r) == ("idle", "no_work")
    assert classify(_w(transfer_overlap_s=0.2), r) == \
        ("comms_bound", "starved_awaiting_kv_transfer")
    assert classify(_w(n_samples=4, running_max=8, decode_tokens=100,
                       preemptions=2), r) == \
        ("capacity_bound", "preemption_storm")
    assert classify(_w(n_samples=4, running_max=8, decode_tokens=100,
                       kv_util_max=0.95, waiting_mean=3.0), r) == \
        ("capacity_bound", "kv_throttled_admission")
    assert classify(_w(n_samples=4, running_max=2, decode_tokens=10,
                       transfer_overlap_s=0.6), r) == \
        ("comms_bound", "migration_dominated")
    assert classify(_w(n_samples=4, running_max=8, max_seqs=64,
                       waiting_mean=5.0, decode_tokens=100), r) == \
        ("queue_bound", "backlog_below_concurrency_cap")
    assert classify(_w(n_samples=4, running_max=64, max_seqs=64,
                       waiting_mean=5.0, decode_tokens=100), r) == \
        ("compute_bound", "busy_no_kv_pressure")


def test_seeded_capacity_bound_trace_classifies_capacity_bound():
    """High concurrency against a starved pool: preemption storms + KV
    saturation — the capacity trap — must read ``capacity_bound``."""
    eng = _trap(max_seqs=2048, n=40, n_pages=400, max_steps=15_000)
    ws = build_windows(eng.events.events)
    rep = attribute(ws)
    assert rep.dominant == "capacity_bound"
    assert rep.busy_fractions["capacity_bound"] > 0.5
    assert max(w.kv_util_max for w in ws.all_windows()) >= 0.99
    assert sum(w.preemptions for w in ws.all_windows()) > 0


def test_seeded_compute_bound_trace_classifies_compute_bound():
    """Same workload shape, ample KV, short outputs at a tight cap: the
    batch runs at its concurrency limit with no KV pressure."""
    eng = _trap(max_seqs=16, n=40, cap_tokens=400)
    ws = build_windows(eng.events.events)
    rep = attribute(ws)
    assert rep.dominant == "compute_bound"
    assert rep.worker_seconds["capacity_bound"] == 0.0
    assert max(w.kv_util_max for w in ws.all_windows()) < 0.5


def test_attribute_fractions_are_a_partition():
    events = _cluster_run(_shrunk(ELASTIC, 30)).events.events
    rep = attribute(build_windows(events))
    assert set(rep.worker_seconds) == set(REGIMES)
    assert abs(sum(rep.fractions.values()) - 1.0) < 1e-9
    total = sum(rep.worker_seconds.values())
    per_worker_total = sum(sum(v["seconds"].values())
                           for v in rep.per_worker.values())
    assert abs(total - per_worker_total) < 1e-9
    d = rep.to_dict()
    assert json.loads(json.dumps(d)) == d


# ------------------------------------------------- purity (REP009 end to end)
def test_attaching_obs_leaves_cluster_summary_byte_identical():
    sc = _shrunk(COLOCATED, 12)
    plain = _cluster_run(sc)
    base = json.dumps(plain.metrics.summary(), sort_keys=True)

    rt = sc.to_cluster()
    build = attach(rt.events)                # live subscriber tap
    rt.submit_trace(sc.trace())
    rt.run()
    assert json.dumps(rt.metrics.summary(), sort_keys=True) == base
    rep = build()
    assert rep["requests"]["n_finished"] == plain.metrics.summary()[
        "n_finished"]


def test_cluster_summary_regimes_param_merges_without_default_change():
    sc = _shrunk(COLOCATED, 10)
    rt = _cluster_run(sc)
    base = rt.metrics.summary()
    assert "regimes" not in base
    rep = bottleneck_report(rt.events.events)
    merged = rt.metrics.summary(regimes=regime_fractions(rep))
    assert merged["regimes"]["dominant"] == rep["regimes"]["dominant"]
    merged.pop("regimes")
    assert json.dumps(merged, sort_keys=True) == \
        json.dumps(base, sort_keys=True)


# ---------------------------------------------------------------- perfetto
def test_perfetto_export_is_valid_chrome_trace():
    events = _cluster_run(_shrunk(DISAGG, 16)).events.events
    ct = to_chrome_trace(events)
    assert set(ct) == {"traceEvents", "displayTimeUnit"}
    assert ct["displayTimeUnit"] == "ms"
    rows = ct["traceEvents"]
    assert json.loads(json.dumps(ct)) == ct     # pure-JSON serialisable

    workers = {e.worker for e in events if e.worker}
    procs = [r for r in rows
             if r["ph"] == "M" and r["name"] == "process_name"]
    assert len(procs) == len(workers)           # one track per worker
    assert {p["args"]["name"] for p in procs} == \
        {f"worker:{w}" for w in workers}
    pids = {p["pid"] for p in procs}
    assert len(pids) == len(procs)              # distinct tracks

    xs = [r for r in rows if r["ph"] == "X"]
    assert xs
    for r in xs:
        assert r["pid"] in pids and r["dur"] > 0 and r["ts"] >= 0
        assert r["name"] in PHASES
    cs = [r for r in rows if r["ph"] == "C"]
    assert {r["name"] for r in cs} == {"kv_pages", "batch"}
    assert all(r["ph"] in ("M", "X", "C") for r in rows)


# --------------------------------------------------------------------- CLI
def _write_trace(tmp_path, name=COLOCATED, n=10):
    events = _cluster_run(_shrunk(name, n)).events.events
    path = str(tmp_path / "trace.jsonl")
    dump_events(events, path)
    return path


def test_cli_report_text_and_json(tmp_path, capsys):
    path = _write_trace(tmp_path)
    assert obs_main(["report", path]) == 0
    out = capsys.readouterr().out
    assert "bottleneck report" in out and "dominant" in out
    assert obs_main(["report", path, "--json", "--window", "0.5"]) == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["window_s"] == 0.5
    assert rep["regimes"]["dominant"] in REGIMES


def test_cli_perfetto_writes_loadable_json(tmp_path, capsys):
    path = _write_trace(tmp_path)
    out = str(tmp_path / "trace.perfetto.json")
    assert obs_main(["perfetto", path, "-o", out]) == 0
    with open(out) as f:
        ct = json.load(f)
    assert ct["traceEvents"]
    assert capsys.readouterr().out.startswith("wrote ")


def test_cli_exits_2_on_unreadable_or_empty_input(tmp_path, capsys):
    with pytest.raises(SystemExit) as exc:
        obs_main(["report", str(tmp_path / "missing.jsonl")])
    assert exc.value.code == 2
    bad = tmp_path / "bad.jsonl"
    bad.write_text("not json\n")
    with pytest.raises(SystemExit) as exc:
        obs_main(["report", str(bad)])
    assert exc.value.code == 2
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    with pytest.raises(SystemExit) as exc:
        obs_main(["perfetto", str(empty), "-o", str(tmp_path / "o.json")])
    assert exc.value.code == 2
    capsys.readouterr()


def test_render_text_mentions_every_regime_and_phase(tmp_path):
    events = _cluster_run(_shrunk(COLOCATED, 8)).events.events
    txt = render_text(bottleneck_report(events), title="x")
    for name in REGIMES + PHASES:
        assert name in txt


# ------------------------------------------------------- benchmark wiring
def _common():
    root = os.path.join(os.path.dirname(__file__), "..")
    if os.path.abspath(root) not in (os.path.abspath(p) for p in sys.path):
        sys.path.insert(0, root)
    from benchmarks import _common as mod
    return mod


def test_benchmark_report_flag_prints_after_engine_and_cluster_runs(capsys):
    mod = _common()
    sc = _shrunk(COLOCATED, 6)
    mod.set_report(True)
    try:
        mod.run_closed(sc, cap_tokens=64)
        out = capsys.readouterr().out
        assert "bottleneck report" in out and sc.name in out

        rt = mod.make_cluster(sc)
        rt.submit_trace(sc.trace())
        rt.run()
        out = capsys.readouterr().out
        assert "bottleneck report" in out       # printed on run_end
    finally:
        mod.set_report(False)
    mod.run_closed(sc, cap_tokens=64)
    assert "bottleneck report" not in capsys.readouterr().out


def test_run_closed_with_report_returns_both(capsys):
    mod = _common()
    sc = _shrunk(COLOCATED, 6)
    summary, rep = mod.run_closed_with_report(sc, cap_tokens=64)
    capsys.readouterr()
    assert summary["n_finished"] == rep["requests"]["n_finished"] == 6
    assert rep["regimes"]["dominant"] in REGIMES
