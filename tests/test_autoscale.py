"""Elastic autoscaling: arrival-process validation, piecewise-rate traffic,
trace persistence, worker lifecycle (cold start / graceful drain),
controller behaviour, worker-second accounting, and static-path identity."""
import dataclasses

import pytest

from repro.configs.paper_models import DS_DISTILL_8B
from repro.core import perf_model as pm
from repro.cluster import (AutoscaleController, ClusterConfig, ClusterRuntime,
                           GammaProcess, PiecewiseRateProcess, PoissonProcess,
                           ScalingSignals, SLOGuard, TargetUtilization,
                           TraceEntry, TraceProcess, load_trace, make_trace,
                           make_sim_worker, save_trace)
from repro.data.reasoning import REASONING

CFG = DS_DISTILL_8B
PLAN = pm.ParallelismPlan()


def _worker(name="", role="colocated", n_pages=3000, max_seqs=64):
    return make_sim_worker(CFG, PLAN, role=role, name=name, n_pages=n_pages,
                           max_seqs=max_seqs)


# -------------------------------------------------- arrival-process validation
@pytest.mark.parametrize("rate", [0.0, -1.0])
def test_poisson_rejects_nonpositive_rate(rate):
    with pytest.raises(ValueError, match="rate > 0"):
        PoissonProcess(rate=rate)


@pytest.mark.parametrize("kw", [dict(rate=0.0), dict(rate=-2.0),
                                dict(rate=1.0, cv=0.0),
                                dict(rate=1.0, cv=-0.5)])
def test_gamma_rejects_nonpositive_params(kw):
    with pytest.raises(ValueError):
        GammaProcess(**kw)


# ------------------------------------------------------- piecewise-rate process
def test_piecewise_validation():
    with pytest.raises(ValueError, match="at least one"):
        PiecewiseRateProcess(phases=())
    with pytest.raises(ValueError, match="durations"):
        PiecewiseRateProcess(phases=((0.0, 5.0),))
    with pytest.raises(ValueError, match="durations"):
        PiecewiseRateProcess(phases=((10.0, 5.0), (-1.0, 2.0)))
    with pytest.raises(ValueError, match="rates"):
        PiecewiseRateProcess(phases=((10.0, -5.0),))
    with pytest.raises(ValueError, match="rate > 0"):
        PiecewiseRateProcess(phases=((10.0, 0.0), (5.0, 0.0)))


def test_piecewise_rate_at():
    p = PiecewiseRateProcess(phases=((10.0, 2.0), (5.0, 8.0)), repeat=True)
    assert p.rate_at(0.0) == 2.0
    assert p.rate_at(9.99) == 2.0
    assert p.rate_at(10.0) == 8.0
    assert p.rate_at(14.9) == 8.0
    assert p.rate_at(15.0) == 2.0          # cycles
    assert p.rate_at(25.0) == 8.0
    q = PiecewiseRateProcess(phases=((10.0, 2.0), (5.0, 8.0)), repeat=False)
    assert q.rate_at(100.0) == 8.0         # last phase extends forever


def test_piecewise_times_monotone_and_deterministic():
    p = PiecewiseRateProcess(phases=((10.0, 1.0), (10.0, 10.0)))
    ts = p.times(100, seed=3)
    assert ts == sorted(ts)
    assert len(ts) == 100
    assert ts == p.times(100, seed=3)      # same seed, same trace
    assert ts != p.times(100, seed=4)


def test_piecewise_density_tracks_rate():
    """Arrivals concentrate in high-rate phases: the 10x phase of a repeating
    (low, high) schedule should hold the vast majority of arrivals."""
    p = PiecewiseRateProcess(phases=((10.0, 0.5), (10.0, 10.0)))
    ts = p.times(400, seed=0)
    in_high = sum(1 for t in ts if (t % 20.0) >= 10.0)
    # expected share ~ 10/(10+0.5) = 95%
    assert in_high / len(ts) > 0.85


def test_piecewise_zero_rate_phase_is_a_gap():
    p = PiecewiseRateProcess(phases=((5.0, 4.0), (5.0, 0.0)))
    ts = p.times(200, seed=1)
    assert all((t % 10.0) < 5.0 for t in ts)   # nothing lands in the gap


def test_piecewise_nonrepeat_zero_tail_raises():
    p = PiecewiseRateProcess(phases=((1.0, 5.0), (1.0, 0.0)), repeat=False)
    with pytest.raises(ValueError, match="rate 0"):
        p.times(1000, seed=0)


# --------------------------------------------------------- trace persistence
def test_save_load_trace_roundtrip(tmp_path):
    trace = make_trace(PoissonProcess(rate=5.0), REASONING, 20, seed=7,
                       osl_cap=300)
    trace = [dataclasses.replace(e, slo_class="interactive" if i % 2 else "")
             for i, e in enumerate(trace)]
    path = str(tmp_path / "trace.jsonl")
    save_trace(path, trace)
    back = load_trace(path)
    assert back == trace                   # arrival, isl, osl AND slo_class


def test_trace_process_short_trace_raises():
    with pytest.raises(ValueError, match="need 5"):
        TraceProcess([0.0, 1.0, 2.0]).times(5)


# ------------------------------------------------------------ worker naming
def test_worker_auto_names_unique():
    """Regression: auto-names derived from id(engine) collided after GC
    reused object ids (the autoscaler mints workers in a loop); the monotonic
    counter cannot."""
    names = [_worker().name for _ in range(64)]
    assert len(set(names)) == len(names)
    rt_names = [_worker(role="decode").name for _ in range(8)]
    assert all(n.startswith("decode-") for n in rt_names)


# ------------------------------------------------------- add/retire lifecycle
def test_add_worker_pays_cold_start():
    rt = ClusterRuntime([_worker("co0"), _worker("co1")], ClusterConfig())
    w = _worker("co2")
    t_active = rt.add_worker(w, at=5.0, cold_start_extra_s=2.0)
    load = pm.weight_load_time(CFG, PLAN, pm.H200, 2)
    assert t_active == pytest.approx(5.0 + load + 2.0)
    assert w.t_join == 5.0 and w.t_active == t_active
    # warming, not yet routable
    assert w not in rt.colocated_pool
    assert rt.warming_count("colocated") == 1
    rt._activate_warming(t_active)
    assert w in rt.colocated_pool and rt.warming_count("colocated") == 0
    assert w.engine.now == pytest.approx(t_active)


def test_add_worker_rejects_duplicate_name_and_bad_role():
    rt = ClusterRuntime([_worker("co0")], ClusterConfig())
    with pytest.raises(ValueError, match="already in fleet"):
        rt.add_worker(_worker("co0"))
    with pytest.raises(ValueError, match="colocated fleet"):
        rt.add_worker(_worker("p0", role="prefill"))


def test_retire_worker_graceful_drain():
    ws = [_worker("co0"), _worker("co1")]
    rt = ClusterRuntime(ws, ClusterConfig())
    # load one request onto each worker, then retire co1 mid-flight
    rt.submit(100, 50, arrival=0.0)
    rt.submit(100, 50, arrival=0.0)
    rt._route_arrivals()
    assert all(w.has_work for w in ws)
    victim = rt.retire_worker(worker=ws[1], at=0.5)
    assert victim is ws[1]
    assert victim not in rt.colocated_pool      # unroutable immediately
    assert victim.draining and victim.t_retire is None   # still draining
    rt.run()
    assert victim.t_retire is not None
    assert victim.t_retire >= 0.5               # never before the request
    # its in-flight request finished (graceful, not dropped)
    assert len(victim.engine.metrics.finished) == 1


def test_retire_last_routable_worker_refused():
    rt = ClusterRuntime([_worker("co0")], ClusterConfig())
    with pytest.raises(ValueError, match="last routable"):
        rt.retire_worker(role="colocated")


def test_retire_idle_worker_charges_to_decision_time():
    """An idle retiree's clock lags the fleet; decommission must stamp the
    decision time, not the stale engine clock (worker-seconds would otherwise
    be undercounted)."""
    rt = ClusterRuntime([_worker("co0"), _worker("co1")], ClusterConfig())
    w = rt.retire_worker(worker=rt.workers[1], at=7.0)
    assert w.t_retire == pytest.approx(7.0)
    assert w.active_window(100.0) == pytest.approx(7.0)


# ----------------------------------------------------------- scaling signals
def test_signals_ewma_holds_on_none():
    s = ScalingSignals(ewma_alpha=0.5)
    s.observe(kv_util=0.8, attainment=1.0, arrival_rate=2.0)
    s.observe(kv_util=0.4, attainment=None, arrival_rate=2.0)
    assert s.kv_util == pytest.approx(0.6)
    assert s.slo_attainment == pytest.approx(1.0)   # held, not decayed


def test_signals_surge_needs_warmup():
    s = ScalingSignals(ewma_alpha=0.8, warmup_ticks=4)
    s.observe(arrival_rate=5.0)            # noisy first sample
    s.observe(arrival_rate=1.0)
    assert s.surge_ratio() == 1.0          # still warming up: no surge
    s.observe(arrival_rate=1.0)
    s.observe(arrival_rate=1.0)
    # warmup baseline is the arithmetic mean (2.0), not an EWMA anchored on
    # the noisy first sample
    assert s.arrival_rate_slow == pytest.approx(2.0)
    s.observe(arrival_rate=10.0)
    assert s.surge_ratio() > 2.0           # warmed up: the step is visible


def test_target_utilization_hysteresis():
    pol = TargetUtilization(target=0.6, band=0.15)
    s = ScalingSignals()
    s.kv_util, s.queue_depth = 0.6, 0.0
    assert pol.desired_delta(s, 2) == 0    # inside the band: hold
    s.kv_util = 0.8
    assert pol.desired_delta(s, 2) == 1
    s.kv_util = 0.97
    assert pol.desired_delta(s, 2) == 2    # saturation imminent: two steps
    s.kv_util = 0.3
    assert pol.desired_delta(s, 2) == -1
    s.queue_depth = 5.0                    # backlog blocks scale-down
    assert pol.desired_delta(s, 2) == 2


def test_slo_guard_asymmetry():
    pol = SLOGuard(attain_floor=0.9, scale_down_util=0.35)
    s = ScalingSignals()
    s.slo_attainment, s.kv_util, s.queue_depth = 0.7, 0.5, 0.0
    assert pol.desired_delta(s, 2) >= 1    # attainment hurt: scale up
    s.slo_attainment = 0.95
    assert pol.desired_delta(s, 2) == 0    # safe but not idle: hold
    s.kv_util = 0.2
    assert pol.desired_delta(s, 2) == -1   # safe AND idle: shrink


# ------------------------------------------------------- controller end-to-end
def _controller_runtime(policy, *, n0=1, min_w=1, max_w=4, tick_s=0.5,
                        cooldown_s=1.0, ewma_alpha=0.7):
    seq = iter(range(n0, 100))

    def factory():
        return _worker(f"el{next(seq)}")

    ctl = AutoscaleController(
        policy, factory, role="colocated", min_workers=min_w,
        max_workers=max_w, tick_s=tick_s, cooldown_s=cooldown_s,
        ewma_alpha=ewma_alpha)
    rt = ClusterRuntime([_worker(f"el{i}") for i in range(n0)],
                        ClusterConfig(), autoscaler=ctl)
    return rt, ctl


def test_controller_grows_and_shrinks_under_piecewise_load():
    proc = PiecewiseRateProcess(phases=((6.0, 0.5), (6.0, 10.0), (12.0, 0.3)),
                                repeat=False)
    trace = make_trace(proc, REASONING, 50, seed=5, osl_cap=200)
    rt, ctl = _controller_runtime(SLOGuard(attain_floor=0.9), n0=1,
                                  max_w=4)
    rt.submit_trace(trace)
    m = rt.run()
    kinds = [e.kind for e in m.scaling_events]
    assert "scale_up" in kinds             # grew into the peak
    assert "retire" in kinds               # shrank back after it
    peak_pool = max(e.pool_size for e in m.scaling_events
                    if e.kind == "join")
    assert peak_pool <= 4                  # bounds respected
    assert len(rt.colocated_pool) >= 1     # never below min
    assert m.summary()["n_finished"] == 50


def test_controller_bounds_and_cooldown():
    rt, ctl = _controller_runtime(TargetUtilization(), n0=2, min_w=2, max_w=3,
                                  cooldown_s=100.0)
    # force a scale-up decision every tick: utilization pinned high
    ctl.signals.kv_util = 0.99
    ctl.signals.queue_depth = 50.0
    ctl.tick(rt, 1.0)
    assert len(rt.workers) == 3            # clamped to max_workers
    ctl.signals.kv_util = 0.99
    ctl.tick(rt, 2.0)
    assert len(rt.workers) == 3            # at the bound
    # now force scale-down: cooldown (100s) must block it
    rt._activate_warming(10.0)
    ctl.signals.kv_util = 0.01
    ctl.signals.queue_depth = 0.0
    ctl.signals.slo_attainment = 1.0
    ctl.tick(rt, 10.0)
    assert len(rt.colocated_pool) == 3     # cooldown held
    ctl.tick(rt, 200.0)
    assert len(rt.colocated_pool) == 2     # cooldown expired; min respected


def test_controller_observation_is_read_only():
    """A tick that takes no action must not advance any engine clock — the
    no-op-controller run must stay bit-identical to the static path."""
    rt, ctl = _controller_runtime(SLOGuard(), n0=2, min_w=2, max_w=2)
    rt.submit(200, 50, arrival=0.0)
    rt._route_arrivals()
    clocks = [w.engine.now for w in rt.workers]
    ctl.tick(rt, 0.25)
    assert [w.engine.now for w in rt.workers] == clocks
    assert len(rt.workers) == 2


# -------------------------------------------------- worker-second accounting
def test_worker_seconds_static_fleet():
    ws = [_worker("co0"), _worker("co1")]
    rt = ClusterRuntime(ws, ClusterConfig())
    rt.submit_trace(make_trace(PoissonProcess(rate=4.0), REASONING, 10,
                               seed=2, osl_cap=150))
    m = rt.run()
    s = m.summary()
    # a static fleet is provisioned wall-to-wall: n_workers * duration
    assert s["worker_seconds"] == pytest.approx(2 * s["duration_s"])
    assert s["throughput_tok_per_worker_s"] == pytest.approx(
        s["throughput_tok_s"] / 2)


def test_worker_seconds_elastic_fleet_charges_partial_windows():
    ws = [_worker("co0"), _worker("co1")]
    rt = ClusterRuntime(ws, ClusterConfig())
    rt.submit_trace(make_trace(PoissonProcess(rate=4.0), REASONING, 10,
                               seed=2, osl_cap=150))
    w2 = _worker("co2")
    rt.add_worker(w2, at=1.0)
    m = rt.run()
    s = m.summary()
    t0 = min(r.arrival for r in rt.submitted)
    end = m.t_end
    # co2 joined at t=1: its window runs 1 -> makespan, not t0 -> makespan
    assert s["worker_seconds"] == pytest.approx(2 * (end - t0) + (end - 1.0))
    assert s["workers"]["co2"]["t_join"] == 1.0


# ----------------------------------------------------- static-path identity
def test_noop_autoscaler_is_bit_identical_to_static():
    """min == max == initial count: the controller observes every tick but
    can never act — the run must be indistinguishable from autoscaler=None
    (the acceptance bar for threading elasticity through the event loop)."""
    trace = make_trace(PoissonProcess(rate=6.0), REASONING, 30, seed=9,
                       osl_cap=200)

    def run(with_ctl):
        ws = [_worker(f"s{i}") for i in range(2)]
        ctl = None
        if with_ctl:
            ctl = AutoscaleController(
                SLOGuard(), lambda: _worker("never"), role="colocated",
                min_workers=2, max_workers=2, tick_s=0.5)
        rt = ClusterRuntime(ws, ClusterConfig(), autoscaler=ctl)
        rt.submit_trace(trace)
        m = rt.run()
        s = m.summary()
        s.pop("n_scaling_events")
        return s

    assert run(False) == run(True)
