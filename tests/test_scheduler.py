"""Property-based tests (hypothesis) for the paged allocator and scheduler
invariants, plus direct preemption-semantics checks."""
import pytest

pytest.importorskip(
    "hypothesis", reason="property-based tests need the 'test' extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.admission import AdmissionPolicy
from repro.core.kv_cache import PagedAllocator
from repro.core.request import Request, State
from repro.core.scheduler import Scheduler, SchedulerConfig


# --------------------------------------------------------------- allocator
@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 19), st.integers(1, 400),
                          st.booleans()), max_size=60),
       st.integers(8, 64))
def test_allocator_invariants(ops, n_pages):
    a = PagedAllocator(n_pages=n_pages, page_size=16)
    live = {}
    for rid, tokens, do_free in ops:
        if do_free:
            a.free(rid)
            live.pop(rid, None)
        else:
            tokens = max(tokens, live.get(rid, 0))   # grow is monotone
            ok = a.grow(rid, tokens)
            if ok:
                live[rid] = tokens
        # invariants
        assert 0 <= a.free_pages <= a.n_pages
        assert a.used_pages == sum(a.pages_for(t) for t in live.values())
        allocated = [p for r in live for p in a.table(r)]
        assert len(allocated) == len(set(allocated)), "page double-booked"
        assert 0.0 <= a.utilization() <= 1.0
        assert 0.0 <= a.internal_fragmentation() <= 1.0
    for r in list(live):
        a.free(r)
    assert a.free_pages == a.n_pages


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 50), st.integers(1, 31))
def test_allocator_pages_for(tokens, page):
    a = PagedAllocator(n_pages=1000, page_size=page)
    p = a.pages_for(tokens)
    assert (p - 1) * page < tokens <= p * page


# --------------------------------------------------------------- scheduler
def _mk_sched(n_pages=64, max_seqs=8, budget=256, chunk=32, mode="naive"):
    alloc = PagedAllocator(n_pages=n_pages, page_size=16)
    return Scheduler(SchedulerConfig(max_seqs, budget, chunk), alloc,
                     AdmissionPolicy(mode=mode)), alloc


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 80), st.integers(1, 60)),
                min_size=1, max_size=20),
       st.integers(16, 128), st.integers(1, 8))
def test_scheduler_invariants(reqs, n_pages, max_seqs):
    sched, alloc = _mk_sched(n_pages=n_pages, max_seqs=max_seqs)
    for i, (isl, osl) in enumerate(reqs):
        sched.submit(Request(rid=i, prompt=[1] * isl, max_new_tokens=osl))
    for _ in range(3000):
        if not sched.has_work:
            break
        plan = sched.plan_step()
        # token budget respected
        assert plan.prefill_tokens + len(plan.decode) \
            <= sched.cfg.max_num_batched_tokens
        # every running request holds pages covering its context
        for r in sched.running:
            assert len(alloc.table(r.rid)) * 16 >= min(
                r.prompt_pos, r.context_len)
        assert len(sched.running) <= max(sched.cfg.max_num_seqs, 1)
        # drive progress like the engine does
        for req, chunk in plan.prefill:
            req.prompt_pos += chunk
            if req.prefill_done:
                req.prompt_pos -= req.resume_extra   # fold regenerated prefix
                req.resume_extra = 0
                req.output.append(0)
                req.generated += 1
        for r in plan.decode:
            r.output.append(0)
            r.generated += 1
        for r in [*plan.decode, *[q for q, _ in plan.prefill]]:
            if r in sched.running and r.done and r.prefill_done:
                sched.finish(r)
    assert not sched.has_work, "scheduler deadlocked"
    assert alloc.used_pages == 0


def test_preemption_recompute_semantics():
    """Filling the pool forces preemption of the youngest running request;
    the victim re-prefills its whole context (prompt + generated)."""
    sched, alloc = _mk_sched(n_pages=10, max_seqs=4, budget=512, chunk=64)
    a = Request(rid=0, prompt=[1] * 60, max_new_tokens=80, arrival=0.0)
    b = Request(rid=1, prompt=[1] * 60, max_new_tokens=80, arrival=1.0)
    sched.submit(a)
    sched.submit(b)
    preempted_any = False
    for _ in range(400):
        if not sched.has_work:
            break
        plan = sched.plan_step()
        if plan.preempted:
            preempted_any = True
            v = plan.preempted[0]
            assert v.arrival >= a.arrival     # youngest-first victim
            assert v.resume_extra == v.generated
            assert v.recomputed_tokens > 0
            # the victim either waits or was immediately re-admitted with a
            # fresh prefill chunk (prompt_pos restarted either way)
            assert v.prompt_pos <= sched.cfg.chunk_size
        for req, chunk in plan.prefill:
            req.prompt_pos += chunk
            if req.prefill_done:
                req.prompt_pos -= req.resume_extra   # fold regenerated prefix
                req.resume_extra = 0
                req.output.append(0)
                req.generated += 1
        for r in plan.decode:
            r.output.append(0)
            r.generated += 1
        for r in [*plan.decode, *[q for q, _ in plan.prefill]]:
            if r in sched.running and r.done and r.prefill_done:
                sched.finish(r)
    assert preempted_any, "pool was sized to force preemption"
    assert a.state == State.FINISHED and b.state == State.FINISHED
    assert a.generated == 80 and b.generated == 80


def test_failed_grow_leaves_no_table_stub():
    """A grow() that fails for lack of pages must not create an empty table
    entry for the rid (all-or-nothing): the stub lingered forever when an
    ``inject`` retry landed on another worker (caught by the sim sanitizer's
    only-running-requests-hold-pages invariant)."""
    a = PagedAllocator(n_pages=2, page_size=16)
    assert a.grow(0, 32)                     # takes both pages
    assert not a.grow(1, 16)                 # pool exhausted
    assert 1 not in a._tables
    assert a.tokens_of(1) == 0
    # a rid that already holds pages keeps them across a failed grow
    assert not a.grow(0, 64)
    assert len(a.table(0)) == 2 and a.tokens_of(0) == 32


def test_kv_aware_admission_blocks_overcommit():
    """Obs 1/8: the KV-aware policy refuses admission that naive accepts."""
    naive, _ = _mk_sched(n_pages=32, max_seqs=16, mode="naive")
    aware, _ = _mk_sched(n_pages=32, max_seqs=16, mode="kv_aware")
    for s in (naive, aware):
        for i in range(8):
            s.submit(Request(rid=i, prompt=[1] * 16,
                             max_new_tokens=400))   # each fits; 8 overcommit
    pn = naive.plan_step()
    pa = aware.plan_step()
    assert len(pn.admitted) > len(pa.admitted)
    assert len(pa.admitted) <= 1
