"""Head-padding and sharding-rule properties (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property-based tests need the 'test' extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.registry import ARCHS, get_config
from repro.parallel.sharding import (ParallelContext, kv_to_orig,
                                     padded_heads, q_to_orig)


@settings(max_examples=200, deadline=None)
@given(st.integers(1, 160), st.integers(0, 6), st.sampled_from([1, 2, 4, 8, 16]))
def test_padded_heads_properties(h, kv_div_pow, tp):
    # kv heads divide q heads (GQA invariant); kv == h is MHA
    divs = [d for d in range(1, h + 1) if h % d == 0]
    kv = divs[min(kv_div_pow, len(divs) - 1)]
    hp, kvp = padded_heads(h, kv, tp)
    assert hp >= h and kvp >= min(kv, hp)
    assert hp % tp == 0 and kvp % tp == 0
    assert hp % kvp == 0                       # integral group size
    if kv < h:
        assert kvp % kv == 0                   # exact replica tiling


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_padded_heads_for_assigned_archs_tp16(arch):
    cfg = get_config(arch)
    hp, kvp = padded_heads(cfg.n_heads, cfg.n_kv_heads, 16)
    assert hp % 16 == 0 and kvp % 16 == 0 and hp % kvp == 0
    qmap = q_to_orig(hp, kvp, cfg.n_heads, cfg.n_kv_heads)
    kvmap = kv_to_orig(kvp, cfg.n_heads, cfg.n_kv_heads)
    # every original q head appears exactly once
    used = qmap[qmap >= 0]
    assert sorted(used.tolist()) == list(range(cfg.n_heads))
    # padded q slot group must attend a replica of its original kv head
    g = hp // kvp
    for slot, orig_q in enumerate(qmap):
        if orig_q < 0:
            continue
        kv_slot = slot // g
        orig_kv = kvmap[kv_slot]
        if cfg.n_kv_heads < cfg.n_heads:
            expected = orig_q // (cfg.n_heads // cfg.n_kv_heads)
            assert orig_kv == expected, (arch, slot)
        else:
            assert orig_kv == orig_q


def test_rules_override_and_specs():
    ctx = ParallelContext(mesh=None, rules_override={"cache_seq": "data"})
    spec = ctx.spec("layers", "cache_batch", "cache_seq", "cache_kv", None)
    assert spec[2] == "data"
    assert spec[3] == "model"
    ctx2 = ParallelContext(mesh=None, fsdp_axis=None)
    assert ctx2.spec("embed")[0] is None       # FSDP disabled
