"""Decision plane: frozen WorkerView/FleetView snapshots, view purity,
typed infeasibility, decode→decode rebalancing, and the Capacity-Bound
scaling signal."""
import dataclasses

import pytest

from repro.configs.paper_models import DS_DISTILL_8B
from repro.core import perf_model as pm
from repro.core.kv_cache import KVView
from repro.cluster import (ClusterConfig, ClusterRuntime, KVPressureRebalancer,
                           NoFeasibleWorker, RebalanceDecision, StragglerTracker,
                           eligible_indices, fleet_snapshot, make_sim_worker,
                           snapshot)
from repro.cluster.autoscale import SLOGuard, ScalingSignals
from repro.cluster.view import FleetView, RequestView, WorkerView

CFG = DS_DISTILL_8B
PLAN = pm.ParallelismPlan()


def _worker(name="w0", role="colocated", n_pages=3000, max_seqs=64):
    return make_sim_worker(CFG, PLAN, role=role, name=name, n_pages=n_pages,
                           max_seqs=max_seqs)


def _busy_worker(name="w0", n_reqs=6, steps=40):
    """A worker stopped mid-run: running + waiting + gated arrivals, so a
    snapshot exercises every field."""
    w = _worker(name)
    for i in range(n_reqs):
        w.engine.submit(400 + 40 * i, 200, arrival=0.01 * i)
    w.engine.submit(300, 100, arrival=10 ** 6)   # gated: engine-level work
    w.engine.run(max_steps=steps)
    return w


def _engine_fingerprint(w):
    e = w.engine
    return (
        e.now, e.alloc.used_pages, e.alloc.free_pages,
        tuple((r.rid, r.generated, r.context_len, r.prefill_done)
              for r in e.sched.running),
        tuple((r.rid, r.slo_class) for r in e.sched.waiting),
        e.sched.n_preemptions, len(e.metrics.finished), len(e._pending),
    )


# ----------------------------------------------------------------- snapshots
def test_snapshot_reflects_engine_state():
    w = _busy_worker()
    v = snapshot(w)
    e = w.engine
    assert v.name == "w0" and v.role == "colocated"
    assert v.now == e.now
    assert v.n_running == len(e.sched.running)
    assert v.n_waiting == len(e.sched.waiting)
    assert v.kv_util == e.alloc.utilization()
    assert v.capacity_tokens == e.alloc.n_pages * e.alloc.page_size
    assert v.queue_depth == v.n_running + v.n_waiting
    assert v.max_seqs == 64 and not v.warming and not v.draining
    # gated far-future arrival: engine has work the scheduler can't see
    assert v.has_work and (v.sched_has_work
                           == bool(e.sched.waiting or e.sched.running))
    assert len(v.running_reqs) == v.n_running
    for rv in v.running_reqs:
        assert rv.remaining >= 0 and rv.context_len >= rv.isl


def test_view_construction_and_reading_are_pure():
    """Building and fully reading views never mutates engine state — the
    decision plane is observation-only."""
    ws = [_busy_worker(f"co{i}") for i in range(3)]
    rt = ClusterRuntime(ws, ClusterConfig())
    before = [_engine_fingerprint(w) for w in ws]
    for _ in range(2):                      # twice: idempotent observation
        fleet = fleet_snapshot(rt)
        for v in fleet.workers:
            (v.n_pages, v.page_size, v.capacity_tokens, v.queue_depth,
             v.kv_util, v.predicted_headroom_pages(), v.fits(500, 200),
             v.pages_for(777), v.candidate_pages(500, 200),
             v.waiting_by_class, v.running_reqs, v.step_ewma)
        (fleet.pool("colocated"), fleet.warming_count("colocated"),
         fleet.worker("co1"), fleet.inflight_migrations,
         fleet.inflight_rebalances, fleet.arrivals, fleet.finished)
    assert [_engine_fingerprint(w) for w in ws] == before


def test_views_are_frozen_snapshots():
    w = _busy_worker()
    v = snapshot(w)
    util_then = v.kv_util
    w.engine.alloc.grow(10 ** 6, 3 * w.engine.alloc.page_size)
    assert v.kv_util == util_then           # old view keeps old state
    assert snapshot(w).kv_util > util_then  # fresh view sees the growth
    with pytest.raises(dataclasses.FrozenInstanceError):
        v.kv_util = 0.0


def test_interleaved_view_building_is_inert_on_event_stream():
    """A run that builds (and fully reads) a FleetView on every event is
    event-stream- and summary-identical to a plain run — the acceptance
    bar for putting observation inside the event loop. The observed run
    also carries the sim sanitizer, which asserts loop invariants around
    every view build."""
    from repro.scenario import get_scenario
    sc = get_scenario("ds8b-4xh200-mixed")
    sc = dataclasses.replace(sc, traffic=dataclasses.replace(
        sc.traffic, n_requests=12))

    def run(observe):
        rt = sc.to_cluster(sanitize=observe)
        rt.events.enable_recording()
        if observe:
            def spy(ev, _rt=rt):
                fleet = _rt.fleet_view()
                for v in fleet.workers:
                    (v.kv_util, v.predicted_headroom_pages(),
                     v.queue_depth, v.fits(100, 10))
            rt.events.subscribe(spy)
        rt.submit_trace(sc.trace())
        m = rt.run()
        return m.summary(slo=sc.slo_map()), [e.to_dict()
                                             for e in rt.events.events]

    s_plain, ev_plain = run(observe=False)
    s_spied, ev_spied = run(observe=True)
    assert s_plain == s_spied
    assert ev_plain == ev_spied


# ------------------------------------------------------------- infeasibility
def test_no_feasible_worker_carries_request_context():
    ws = [_worker("tiny0", n_pages=8), _worker("tiny1", n_pages=4)]
    views = [snapshot(w) for w in ws]
    with pytest.raises(NoFeasibleWorker) as ei:
        eligible_indices(views, 900, 300)
    e = ei.value
    assert isinstance(e, ValueError)        # old callers keep catching it
    assert e.prompt_len == 900 and e.max_new == 300
    assert dict(e.capacities) == {"tiny0": 8 * views[0].page_size,
                                  "tiny1": 4 * views[1].page_size}
    assert "900 in" in str(e) and "tiny1" in str(e)
    rich = e.with_context(rid=7, scenario="unit", arrival=1.5,
                          slo_class="interactive")
    assert rich.rid == 7 and rich.scenario == "unit"
    assert "rid=7" in str(rich) and "'unit'" in str(rich)
    assert "t=1.5" in str(rich) and "interactive" in str(rich)


def test_runtime_surfaces_scenario_name_on_infeasible_route():
    """A route that becomes infeasible mid-run (the only big replica
    retired) aborts with the scenario name and arrival attached."""
    big, small = _worker("big", n_pages=3000), _worker("small", n_pages=16)
    rt = ClusterRuntime([big, small], ClusterConfig(name="hetero-unit"))
    rt.submit(600, 200, arrival=1.0, slo_class="x")  # fits only `big`
    rt.retire_worker(worker=big, at=0.0)
    with pytest.raises(NoFeasibleWorker) as ei:
        rt.run()
    e = ei.value
    assert e.scenario == "hetero-unit"
    assert e.arrival == 1.0 and e.slo_class == "x"
    assert dict(e.capacities) == {"small": 16 * 16}


# ---------------------------------------------------------------- rebalancer
def _wv(name, kv_util=0.5, n_running=4, running=(), role="decode",
        n_pages=100, page_size=16, max_seqs=8, draining=False,
        predicted_used=None):
    used = int(kv_util * n_pages)
    return WorkerView(
        name=name, role=role, prefill_only=False, warming=False,
        draining=draining, now=0.0, has_work=True, sched_has_work=True,
        kv=KVView(n_pages=n_pages, page_size=page_size, used_pages=used,
                  free_pages=n_pages - used),
        kv_util=kv_util,
        predicted_used=used if predicted_used is None else predicted_used,
        osl_est=200.0, n_running=n_running, n_waiting=0, max_seqs=max_seqs,
        preemptions=0, step_ewma=None, waiting_by_class=(),
        running_reqs=tuple(running))


def _rv(rid, urgency=0, arrival=0.0, generated=10, remaining=200,
        prefill_done=True):
    return RequestView(rid=rid, slo_class="", urgency=urgency,
                       arrival=arrival, isl=100, generated=generated,
                       context_len=100 + generated, remaining=remaining,
                       prefill_done=prefill_done)


def _fleet(workers, t=10.0, inflight_rebalances=0):
    return FleetView(
        t=t, workers=tuple(workers),
        pools=(("prefill", ()), ("colocated", ()),
               ("decode", tuple(range(len(workers))))),
        inflight_rebalances=inflight_rebalances)


def test_rebalancer_decides_off_most_pressured_worker():
    rb = KVPressureRebalancer()
    victims = (_rv(1, arrival=0.0), _rv(2, arrival=5.0))  # 2: most recent
    fleet = _fleet([_wv("dec0", kv_util=0.95, running=victims),
                    _wv("dec1", kv_util=0.92, running=(_rv(3),)),
                    _wv("dec2", kv_util=0.20, n_running=1)])
    d = rb.decide(fleet)
    assert d is not None
    assert d.src == "dec0" and d.dst == "dec2" and d.rid == 2
    assert d.kv_util == 0.95 and "dec2" in d.reason


def test_rebalancer_gates():
    victims = (_rv(1), _rv(2))
    pressured = _wv("dec0", kv_util=0.95, running=victims)
    idle = _wv("dec1", kv_util=0.2, n_running=1)
    # below threshold: no decision
    assert KVPressureRebalancer().decide(
        _fleet([_wv("dec0", kv_util=0.5, running=victims), idle])) is None
    # inflight cap
    assert KVPressureRebalancer(max_inflight=1).decide(
        _fleet([pressured, idle], inflight_rebalances=1)) is None
    # singleton pool
    assert KVPressureRebalancer().decide(_fleet([pressured])) is None
    # cooldown: a decision at t blocks the next until t + cooldown_s
    rb = KVPressureRebalancer(cooldown_s=5.0)
    assert rb.decide(_fleet([pressured, idle], t=10.0)) is not None
    assert rb.decide(_fleet([pressured, idle], t=12.0)) is None
    assert rb.decide(_fleet([pressured, idle], t=15.1)) is not None


def test_rebalancer_victim_eligibility():
    idle = _wv("dec1", kv_util=0.2, n_running=1)
    # mid-prefill and nearly-finished requests are never shipped
    bad = (_rv(1, prefill_done=False), _rv(2, remaining=3))
    assert KVPressureRebalancer(min_remaining=64).decide(
        _fleet([_wv("dec0", kv_util=0.95, running=bad), idle])) is None
    # victim order matches engine preemption: least urgent class first,
    # most recently arrived within a class
    mixed = (_rv(1, urgency=5, arrival=9.0), _rv(2, urgency=0, arrival=1.0),
             _rv(3, urgency=0, arrival=2.0))
    d = KVPressureRebalancer().decide(
        _fleet([_wv("dec0", kv_util=0.95, running=mixed), idle]))
    assert d.rid == 3


def test_rebalancer_destination_needs_post_adoption_headroom():
    pressured = _wv("dec0", kv_util=0.95, running=(_rv(1), _rv(2)))
    # peer at 0.85: adopting ~14 pages of victim leaves < 10% headroom
    assert KVPressureRebalancer(dst_headroom=0.10).decide(
        _fleet([pressured, _wv("dec1", kv_util=0.85)])) is None
    # draining and batch-full peers are skipped even with room
    assert KVPressureRebalancer().decide(
        _fleet([pressured, _wv("dec1", kv_util=0.1, draining=True)])) is None
    assert KVPressureRebalancer().decide(
        _fleet([pressured,
                _wv("dec1", kv_util=0.1, n_running=8, max_seqs=8)])) is None
    # among viable peers, most post-adoption headroom wins
    d = KVPressureRebalancer().decide(
        _fleet([pressured, _wv("dec1", kv_util=0.5), _wv("dec2",
                                                         kv_util=0.3)]))
    assert d.dst == "dec2"


def test_rebalance_end_to_end_relieves_pressure():
    """Registry scenario at a CI-scale count: rebalancing fires, migrates
    over the standard eject/transfer/inject path, and strictly reduces
    fleet preemptions vs the identical trace without the hook."""
    from repro.scenario import get_scenario
    sc = get_scenario("ds8b-4xh200-rebalance")
    sc = dataclasses.replace(sc, traffic=dataclasses.replace(
        sc.traffic, n_requests=40))

    def run(s):
        rt = s.to_cluster(sanitize=True)
        rt.events.enable_recording()
        rt.submit_trace(s.trace())
        m = rt.run()
        summ = m.summary(slo=s.slo_map())
        return rt, summ

    rt_on, s_on = run(sc)
    _, s_off = run(dataclasses.replace(sc, rebalance=None))
    reb = [e for e in rt_on.events.events if e.kind == "rebalance"]
    assert reb, "scenario never pressured a decode worker past kv_high"
    for ev in reb:
        d = ev.to_dict()["payload"]
        assert d["src"] != d["dst"] and d["kv_util"] >= 0.90 and d["reason"]
    pre_on = sum(w["preemptions"] for w in s_on["workers"].values())
    pre_off = sum(w["preemptions"] for w in s_off["workers"].values())
    assert pre_on < pre_off
    assert s_on["slo_attainment"] >= s_off["slo_attainment"]
    assert s_on["n_finished"] == 40        # every migrated request finishes


def test_rebalance_decision_on_stale_view_is_dropped():
    """The policy decides on a frozen view; if the fleet moved on (victim
    finished, destination retired), actuation silently drops the decision
    instead of corrupting state."""
    ws = [_worker(f"dec{i}", role="decode") for i in range(2)]
    ws.insert(0, _worker("pre0", role="prefill"))
    rt = ClusterRuntime(ws, ClusterConfig())

    class Stale:
        def decide(self, fleet):
            return RebalanceDecision(rid=10 ** 9, src="dec0", dst="dec1")
    rt.rebalancer = Stale()
    rt._apply_rebalance(RebalanceDecision(rid=10 ** 9, src="dec0",
                                          dst="dec1"))
    rt._apply_rebalance(RebalanceDecision(rid=0, src="ghost", dst="dec1"))
    assert not rt._migrating


# ------------------------------------------------- capacity-bound signal
def test_capacity_frac_fires_a_tick_before_kv_ewma():
    """One replica's preemption storm flips the Capacity-Bound fraction
    immediately, while the pool-mean KV EWMA is still averaging the storm
    away — the guard with the regime trigger scales up a tick earlier."""
    # tick 0: calm; tick 1: one of two replicas storms (fraction 0.5, KV
    # mean still mid-band); tick 2: the mean itself finally crosses
    obs = ({"kv_util": 0.50, "capacity_frac": 0.0},
           {"kv_util": 0.65, "capacity_frac": 0.5},
           {"kv_util": 0.92, "capacity_frac": 0.5})

    def first_fire(guard):
        s = ScalingSignals(ewma_alpha=1.0)   # raw per-tick values
        for i, ob in enumerate(obs):
            s.observe(**ob)
            if guard.desired_delta(s, 2) > 0:
                return i
        return None

    plain = first_fire(SLOGuard())
    regime = first_fire(SLOGuard(capacity_frac_ceiling=0.25))
    assert plain == 2 and regime == 1
    # ceiling=None is bit-identical to the pre-regime controller
    assert first_fire(SLOGuard(capacity_frac_ceiling=None)) == plain


def test_controller_capacity_bound_evidence_from_views():
    """The controller's per-worker Capacity-Bound test uses the repro.obs
    evidence on view fields: preemptions since last tick, or saturated KV
    while requests queue."""
    from repro.cluster.autoscale import AutoscaleController
    c = AutoscaleController(SLOGuard(), worker_factory=lambda: None,
                            role="decode")
    calm = _wv("dec0", kv_util=0.5)
    assert not c._capacity_bound(calm)
    stormed = dataclasses.replace(calm, preemptions=3)
    assert c._capacity_bound(stormed)
    c._last_preempt["dec0"] = 3             # storm already accounted
    assert not c._capacity_bound(stormed)
    throttled = dataclasses.replace(calm, kv_util=0.93)
    assert not c._capacity_bound(throttled)          # saturated but no queue
    queued = dataclasses.replace(throttled, n_waiting=2)
    assert c._capacity_bound(queued)


def test_straggler_tracker_validation():
    with pytest.raises(ValueError):
        StragglerTracker(alpha=0.0)
    tr = StragglerTracker(alpha=0.5)
    tr.note_step("w", 1.0)
    assert tr.get("w") == 1.0               # first observation seeds
    tr.note_step("w", 3.0)
    assert tr.get("w") == 2.0
    tr.forget("w")
    assert tr.get("w") is None
