"""Planner regression against the paper's measured orderings (§IV-§VI).

Absolute seconds differ from the paper (different request caps / engine
versions); the *orderings* — the paper's actual contribution — must hold.
"""
import pytest

from repro.configs.paper_models import (DEEPSEEK_R1_671B, DS_DISTILL_14B,
                                        DS_DISTILL_32B, DS_DISTILL_8B)
from repro.configs.registry import get_config
from repro.core import perf_model as pm, planner


def _by_label(cfg, dtype_bytes=2):
    ests = planner.plan(cfg, pm.H200, 8, dtype_bytes=dtype_bytes)
    return {e.label(): e for e in ests}, ests


def test_small_models_prefer_dp():
    """Obs 5: 8B is DP-dominant; TP8 and every PP plan lose."""
    lab, ests = _by_label(DS_DISTILL_8B)
    best = ests[0]
    assert best.plan.dp >= 4 and best.plan.pp == 1
    assert lab["DP=8"].completion_s < lab["TP=8"].completion_s
    assert lab["DP=8"].completion_s < lab["PP=8"].completion_s
    # paper Fig 7: PP-heavy hybrids are ~3.5x off for small models
    assert lab["TP=4+PP=2"].completion_s > 2.0 * lab["DP=8"].completion_s


def test_14b_dp_beats_tp8():
    lab, ests = _by_label(DS_DISTILL_14B)
    assert ests[0].plan.dp >= 4 and ests[0].plan.pp == 1
    assert lab["DP=8"].completion_s < lab["TP=8"].completion_s
    # DP=8 within the top band (paper: best measured config)
    assert lab["DP=8"].completion_s < 1.2 * ests[0].completion_s


def test_32b_crossover_right_sized_tp():
    """§V-B: DP4xTP2 beats pure TP8 beats pure DP8."""
    lab, _ = _by_label(DS_DISTILL_32B)
    assert lab["DP=4+TP=2"].completion_s < lab["TP=8"].completion_s
    assert lab["TP=8"].completion_s < lab["DP=8"].completion_s
    # TP capacity release (Obs 5): TP=8 frees ~16x the per-replica KV room
    assert lab["TP=8"].kv_capacity_tokens > 8 * lab["DP=8"].kv_capacity_tokens


def test_405b_dense_frontier():
    """§V-C: DP infeasible; TP8 best; PP8 catastrophic (>=5x)."""
    lab, ests = _by_label(get_config("llama3-405b"))
    assert not lab["DP=8"].feasible
    assert ests[0].label() == "TP=8"
    assert lab["PP=8"].completion_s > 5.0 * lab["TP=8"].completion_s


def test_r1_sparse_prefers_hybrid_pp():
    """Obs 6: the MoE+MLA frontier model prefers hybrid PP over TP8."""
    lab, ests = _by_label(DEEPSEEK_R1_671B, dtype_bytes=1)   # fp8 weights
    best = ests[0]
    assert best.plan.pp > 1 and best.plan.tp <= 4
    hybrid = min(lab["TP=2+PP=4"].completion_s, lab["TP=4+PP=2"].completion_s)
    assert hybrid < lab["TP=8"].completion_s


def test_tp_transition_with_scale():
    """Fig 8/9: TP speedup over TP1 grows with model size (sublinear)."""
    wl = planner.Workload()
    sp = {}
    for name, cfg in (("8b", DS_DISTILL_8B), ("32b", DS_DISTILL_32B)):
        t1 = planner.estimate(cfg, pm.ParallelismPlan(dp=1, tp=1), pm.H200, wl)
        t8 = planner.estimate(cfg, pm.ParallelismPlan(dp=1, tp=8), pm.H200, wl)
        sp[name] = t1.completion_s / t8.completion_s
    assert sp["32b"] > sp["8b"]
    # paper: 6.15x; slight super-linearity vs TP1 is legitimate (TP=8 also
    # eliminates the preemption regime TP1 sits in, §V-A)
    assert 2.0 < sp["32b"] < 12.0


def test_v5e_plans_exist_for_all_archs():
    """The planner must produce a feasible plan for every assigned arch on a
    v5e pod slice (operational guidance deliverable)."""
    from repro.configs.registry import ARCHS
    for arch in ARCHS:
        cfg = get_config(arch)
        best = planner.best(cfg, pm.V5E, 256)
        assert best.feasible, f"{arch}: no feasible v5e plan"
        assert best.plan.devices == 256
