"""Per-arch smoke tests (brief deliverable f): reduced config, one forward /
train step on CPU, shape + finiteness asserts, plus prefill->decode
consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.paper_models import DEEPSEEK_R1_671B
from repro.configs.registry import ARCHS, get_smoke_config
from repro.models import transformer as T
from repro.parallel.sharding import single_device_ctx
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step

CTX = single_device_ctx()
KEY = jax.random.PRNGKey(0)


def _tokens(cfg, b=2, s=16):
    return jax.random.randint(KEY, (b, s), 0, cfg.vocab)


def _prefix(cfg, b=2):
    if not cfg.frontend_prefix_len:
        return None
    return jax.random.normal(KEY, (b, cfg.frontend_prefix_len, cfg.d_model))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_smoke(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, KEY, CTX, mode="train", dtype=jnp.float32)
    logits, _ = T.forward(params, _tokens(cfg), cfg, CTX, mode="train",
                          prefix_embeds=_prefix(cfg))
    s_total = 16 + cfg.frontend_prefix_len
    assert logits.shape == (2, s_total, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ["llama3.2-3b", "phi3.5-moe-42b-a6.6b",
                                  "zamba2-2.7b", "xlstm-350m",
                                  "internvl2-76b"])
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1)
    params = T.init_params(cfg, KEY, CTX, mode="train", dtype=jnp.float32)
    opt = init_opt_state(params, ocfg)
    tokens = _tokens(cfg, 2, 16)
    batch = {"tokens": tokens, "labels": tokens}
    pre = _prefix(cfg)
    if pre is not None:
        batch["prefix_embeds"] = pre
    step = jax.jit(make_train_step(cfg, CTX, ocfg))
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree_util.tree_map(lambda a, b: a - b, params, params2), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, KEY, CTX, mode="serve", dtype=jnp.float32)
    tokens = _tokens(cfg, 2, 12)
    nxt = jax.random.randint(jax.random.PRNGKey(1), (2, 1), 0, cfg.vocab)
    full = jnp.concatenate([tokens, nxt], axis=1)
    logits_full, _ = T.forward(params, full, cfg, CTX, mode="serve")
    last, state = T.prefill(params, tokens, cfg, CTX, max_len=16,
                            cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, 11]),
                               rtol=3e-4, atol=3e-4)
    dec, state = T.decode_step(params, state, nxt, cfg, CTX)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(logits_full[:, 12]),
                               rtol=3e-3, atol=3e-3)


def test_mla_paper_model():
    cfg = reduced(DEEPSEEK_R1_671B)
    params = T.init_params(cfg, KEY, CTX, mode="serve", dtype=jnp.float32)
    tokens = _tokens(cfg, 2, 12)
    nxt = jax.random.randint(jax.random.PRNGKey(1), (2, 1), 0, cfg.vocab)
    full = jnp.concatenate([tokens, nxt], axis=1)
    logits_full, _ = T.forward(params, full, cfg, CTX, mode="serve")
    last, state = T.prefill(params, tokens, cfg, CTX, max_len=16,
                            cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, 11]),
                               rtol=3e-4, atol=3e-4)
    # the MLA decode cache is the compressed latent, not per-head KV
    ckv = state["caches"]["moe_stack"]["ckv"]
    assert ckv.shape[-1] == cfg.mla.kv_lora_rank
    dec, _ = T.decode_step(params, state, nxt, cfg, CTX)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(logits_full[:, 12]),
                               rtol=3e-3, atol=3e-3)


def test_swa_decode_masks_outside_window():
    """Sliding-window decode attention must ignore keys beyond the window
    (single-op test: multi-layer receptive fields legitimately exceed w)."""
    from repro.models.attention import decode_attention
    B, S, H, KV, D, w = 2, 32, 4, 2, 16, 8
    q = jax.random.normal(KEY, (B, 1, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, D))
    lens = jnp.full((B,), 20)
    out1 = decode_attention(q, k, v, lens, window=w)
    # perturb cache strictly outside the window (positions <= 20 - 8)
    k2 = k.at[:, :12].set(jax.random.normal(jax.random.PRNGKey(3),
                                            (B, 12, KV, D)))
    v2 = v.at[:, :12].set(jax.random.normal(jax.random.PRNGKey(4),
                                            (B, 12, KV, D)))
    out2 = decode_attention(q, k2, v2, lens, window=w)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)
    # ...and the window must actually matter vs full attention
    out_full = decode_attention(q, k2, v2, lens, window=0)
    assert float(jnp.abs(out_full - out2).max()) > 1e-3


def test_decode_unroll_and_2dtp_match_scan():
    """§Perf levers preserve semantics: unrolled decode == scan decode."""
    from repro.parallel.sharding import ParallelContext
    cfg = get_smoke_config("llama3.2-3b")
    params = T.init_params(cfg, KEY, CTX, mode="serve", dtype=jnp.float32)
    tokens = _tokens(cfg, 2, 10)
    nxt = jax.random.randint(jax.random.PRNGKey(5), (2, 1), 0, cfg.vocab)
    outs = []
    for ctx in (ParallelContext(mesh=None),
                ParallelContext(mesh=None, decode_unroll=True,
                                serve_2d_tp=True)):
        last, st = T.prefill(params, tokens, cfg, ctx, max_len=16,
                             cache_dtype=jnp.float32)
        dec, _ = T.decode_step(params, st, nxt, cfg, ctx)
        outs.append(np.asarray(dec))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)


def test_int8_kv_cache_decode_runs():
    """int8 KV cache (capacity lever) stays finite and roughly consistent."""
    from repro.parallel.sharding import ParallelContext
    cfg = get_smoke_config("llama3.2-3b")
    params = T.init_params(cfg, KEY, CTX, mode="serve", dtype=jnp.float32)
    tokens = _tokens(cfg, 2, 10)
    nxt = jax.random.randint(jax.random.PRNGKey(5), (2, 1), 0, cfg.vocab)
    ctx = ParallelContext(mesh=None, kv_cache_dtype=jnp.int8)
    last, st = T.prefill(params, tokens, cfg, ctx, max_len=16,
                         cache_dtype=jnp.int8)
    assert st["caches"]["dense_stack"]["k"].dtype == jnp.int8
    dec, _ = T.decode_step(params, st, nxt, cfg, ctx)
    assert bool(jnp.isfinite(dec).all())
