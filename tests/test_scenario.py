"""Scenario API: spec round-trips, cross-fidelity consistency, registry."""
import dataclasses
import json

import pytest

from repro.core import perf_model as pm
from repro.scenario import (SCENARIOS, Autoscaler, ModelRef, Scenario,
                            SLOClass, Traffic, WorkerGroup, estimate_fleet,
                            get_scenario, planner_workload, requests, resolve,
                            trace)


def _rich_scenario() -> Scenario:
    """Exercises every schema feature: heterogeneous fleet, gamma traffic,
    two prioritised SLO classes with a traffic mix, non-default numerics."""
    return Scenario(
        name="rich",
        model=ModelRef("ds-distill-32b", dtype_bytes=1, cache_dtype_bytes=1),
        fleet=(WorkerGroup(role="prefill", count=1, hardware="h200",
                           plan=pm.ParallelismPlan(tp=2, ep=2),
                           n_pages=2048, max_seqs=32, prefix="pre"),
               WorkerGroup(role="decode", count=3, hardware="v5e",
                           plan=pm.ParallelismPlan(tp=4, ep=4),
                           chunk_size=256, admission="kv_aware")),
        traffic=Traffic(process="gamma", rate=6.0, cv=2.5,
                        workload="long_reasoning", n_requests=64,
                        osl_cap=2000, seed=7,
                        class_mix=(("interactive", 0.3), ("batch", 0.7))),
        slos=(SLOClass("interactive", ttft_s=0.5, tpot_s=0.02, priority=10),
              SLOClass("batch", ttft_s=30.0)),
        routing="jsq", dispatch="most_headroom", transfer_dtype_bytes=1,
        class_kv_headroom=0.15,
        autoscaler=Autoscaler(policy="slo_guard", role="decode",
                              min_workers=1, max_workers=5, tick_s=1.5,
                              cold_start_extra_s=3.0),
        notes="round-trip fixture")


# ------------------------------------------------------------- dict round trip
def test_dict_round_trip():
    for sc in [_rich_scenario(), *SCENARIOS.values()]:
        assert Scenario.from_dict(sc.to_dict()) == sc


def test_json_round_trip_through_plain_data():
    sc = _rich_scenario()
    # a full json dump/load turns tuples into lists; from_dict must normalise
    back = Scenario.from_json(json.dumps(json.loads(sc.to_json())))
    assert back == sc
    assert isinstance(back.fleet, tuple)
    assert isinstance(back.traffic.arrivals, tuple)


def test_spec_validation():
    with pytest.raises(ValueError):
        WorkerGroup(role="oracle")
    with pytest.raises(ValueError):
        WorkerGroup(count=0)
    with pytest.raises(ValueError):
        Traffic(process="poisson", rate=0.0)
    with pytest.raises(ValueError):
        Traffic(process="fifo")
    with pytest.raises(ValueError):      # prefill without a decode pool
        Scenario(name="x", model=ModelRef("ds-distill-8b"),
                 fleet=(WorkerGroup(role="prefill"),))
    with pytest.raises(KeyError):
        resolve(Scenario(name="x", model=ModelRef("no-such-model"),
                         fleet=(WorkerGroup(),)))
    with pytest.raises(KeyError):
        resolve(Scenario(name="x", model=ModelRef("ds-distill-8b"),
                         fleet=(WorkerGroup(hardware="h9000"),)))
    with pytest.raises(ValueError):      # mix names need a matching SLOClass
        Scenario(name="x", model=ModelRef("ds-distill-8b"),
                 fleet=(WorkerGroup(),),
                 traffic=Traffic(class_mix=(("gold", 1.0),)),
                 slos=(SLOClass("interactive"),))
    with pytest.raises(ValueError):      # non-positive mix weight
        Traffic(class_mix=(("interactive", 0.0),))
    with pytest.raises(ValueError):      # duplicate mix names
        Traffic(class_mix=(("a", 0.5), ("a", 0.5)))
    with pytest.raises(ValueError):      # headroom out of range
        Scenario(name="x", model=ModelRef("ds-distill-8b"),
                 fleet=(WorkerGroup(),), class_kv_headroom=1.0)


def test_autoscaler_spec_validation():
    with pytest.raises(ValueError, match="policy"):
        Autoscaler(policy="oracle")
    with pytest.raises(ValueError, match="role"):
        Autoscaler(role="mystery")
    with pytest.raises(ValueError, match="min_workers"):
        Autoscaler(min_workers=0)
    with pytest.raises(ValueError, match="min_workers"):
        Autoscaler(min_workers=4, max_workers=2)
    with pytest.raises(ValueError, match="tick_s"):
        Autoscaler(tick_s=0.0)
    with pytest.raises(ValueError, match="ewma_alpha"):
        Autoscaler(ewma_alpha=1.5)
    with pytest.raises(ValueError, match="no such group"):
        Scenario(name="x", model=ModelRef("ds-distill-8b"),
                 fleet=(WorkerGroup(role="colocated"),),
                 autoscaler=Autoscaler(role="decode"))
    with pytest.raises(ValueError, match="outside autoscaler bounds"):
        Scenario(name="x", model=ModelRef("ds-distill-8b"),
                 fleet=(WorkerGroup(count=8),),
                 autoscaler=Autoscaler(min_workers=1, max_workers=4))
    # dict coercion works like the other nested specs
    sc = Scenario(name="x", model=ModelRef("ds-distill-8b"),
                  fleet=(WorkerGroup(count=2),),
                  autoscaler={"policy": "target_utilization",
                              "max_workers": 4})
    assert isinstance(sc.autoscaler, Autoscaler)


def test_piecewise_traffic_validation():
    with pytest.raises(ValueError, match="phase"):
        Traffic(process="piecewise")
    with pytest.raises(ValueError, match="duration"):
        Traffic(process="piecewise", phases=((0.0, 5.0),))
    with pytest.raises(ValueError, match="rate > 0"):
        Traffic(process="piecewise", phases=((10.0, 0.0),))
    t = Traffic(process="piecewise", phases=[[10, 2], [5, 8]])
    assert t.phases == ((10.0, 2.0), (5.0, 8.0))   # normalised to tuples


def test_autoscaled_cluster_gets_controller_with_group_matched_factory():
    sc = get_scenario("ds8b-autoscale-diurnal")
    rt = sc.to_cluster()
    assert rt.autoscaler is not None
    assert rt.autoscaler.role == "colocated"
    w = rt.autoscaler.worker_factory()
    # minted replicas match the scaled group exactly and continue its naming
    assert w.name == f"co{sc.fleet[0].count}"
    assert w.engine.alloc.n_pages == rt.workers[0].engine.alloc.n_pages
    assert w.engine.sched.cfg.max_num_seqs == \
        rt.workers[0].engine.sched.cfg.max_num_seqs


# ---------------------------------------------------------------------- trace
def test_closed_traffic_arrives_at_zero_and_is_deterministic():
    sc = Scenario(name="x", model=ModelRef("ds-distill-8b"),
                  fleet=(WorkerGroup(),),
                  traffic=Traffic(process="closed", n_requests=32,
                                  osl_cap=500, seed=5))
    t1, t2 = trace(sc), trace(sc)
    assert t1 == t2
    assert all(e.arrival == 0.0 for e in t1)
    assert all(e.osl <= 500 for e in t1)
    assert len(t1) == 32


def test_lengths_independent_of_arrival_process():
    kw = dict(workload="reasoning", n_requests=16, osl_cap=800, seed=3)
    closed = Scenario(name="a", model=ModelRef("ds-distill-8b"),
                      fleet=(WorkerGroup(),),
                      traffic=Traffic(process="closed", **kw))
    poisson = Scenario(name="b", model=ModelRef("ds-distill-8b"),
                       fleet=(WorkerGroup(),),
                       traffic=Traffic(process="poisson", rate=4.0, **kw))
    assert requests(closed) == requests(poisson)


# ------------------------------------------------- cross-fidelity consistency
def test_plan_concurrency_matches_engine_kv_capacity_explicit_pages():
    sc = Scenario(name="x", model=ModelRef("ds-distill-8b"),
                  fleet=(WorkerGroup(count=1, n_pages=3000, max_seqs=64),),
                  traffic=Traffic(process="closed", n_requests=64,
                                  osl_cap=1200, seed=42))
    eng = sc.to_engine()
    cap_engine = eng.alloc.n_pages * eng.alloc.page_size
    est = estimate_fleet(sc)
    assert est.kv_capacity_tokens == cap_engine
    wl = planner_workload(sc)
    mean_ctx = wl.mean_isl + wl.mean_osl / 2
    assert est.concurrency == int(min(cap_engine / mean_ctx,
                                      sc.fleet[0].max_seqs))
    # the same estimate appears in the ranked sweep (aggregate plan is DP=1)
    assert any(e.plan == est.plan for e in sc.to_plan())


def test_plan_capacity_matches_engine_default_pages_within_one_page():
    sc = Scenario(name="x", model=ModelRef("ds-distill-8b"),
                  fleet=(WorkerGroup(count=1),),
                  traffic=Traffic(process="closed", n_requests=64, seed=0))
    eng = sc.to_engine()
    cap_engine = eng.alloc.n_pages * eng.alloc.page_size
    est = estimate_fleet(sc)
    assert abs(est.kv_capacity_tokens - cap_engine) <= eng.alloc.page_size


def test_estimate_fleet_handles_plans_outside_candidate_sweep():
    # candidate_plans always emits ep == tp; a custom ep must not crash
    sc = Scenario(name="x", model=ModelRef("ds-distill-8b"),
                  fleet=(WorkerGroup(count=2,
                                     plan=pm.ParallelismPlan(tp=2)),),
                  traffic=Traffic(process="closed", n_requests=32, seed=0))
    est = estimate_fleet(sc)
    assert est.feasible and est.plan.ep == 1


def test_planner_fidelity_uses_decode_group_for_disagg():
    sc = Scenario(
        name="x", model=ModelRef("ds-distill-8b"),
        fleet=(WorkerGroup(role="prefill", count=1, max_seqs=8,
                           n_pages=1000),
               WorkerGroup(role="decode", count=3, max_seqs=256,
                           n_pages=3000)),
        traffic=Traffic(process="closed", n_requests=32, osl_cap=1200,
                        seed=0))
    wl = planner_workload(sc)
    assert wl.max_num_seqs == 256       # decode group, not the prefill cap
    # and the KV pinning comes from the decode group's page pool
    est = sc.to_plan()[0]
    assert est.kv_capacity_tokens == 3000 * 16


def test_resolution_is_shared_across_fidelities():
    sc = get_scenario("ds8b-4xh200-disagg")
    r = resolve(sc)
    rt = sc.to_cluster()
    # per-group page pools in the cluster match the resolved spec
    by_role = {}
    for w in rt.workers:
        by_role.setdefault(w.role, []).append(w.engine.alloc.n_pages)
    for rg in r.groups:
        assert by_role[rg.group.role] == [rg.n_pages] * rg.group.count
    # engine fidelity builds the same replica as the cluster's group 0
    eng = sc.to_engine(group=0)
    assert eng.alloc.n_pages == r.groups[0].n_pages
    assert eng.sched.cfg.prefill_only   # group 0 is the prefill group


# ------------------------------------------------------------------- registry
@pytest.mark.parametrize("name,devices", [
    ("ds8b-8xh200-dp8", 8), ("ds14b-8xh200-dp8", 8),
    ("ds32b-8xh200-dp4tp2", 8), ("llama405b-8xh200-tp8", 8),
    ("r1-8xh200-pp4tp2", 8), ("ds8b-4xh200-colocated", 4),
    ("ds8b-4xh200-disagg", 4), ("ds8b-4xh200-mixed", 4),
])
def test_registry_scenarios_resolve_and_plan(name, devices):
    sc = get_scenario(name)
    assert sc.n_devices == devices
    r = resolve(sc)
    assert r.model.name == sc.model.name
    if len(sc.fleet) == 1:
        est = estimate_fleet(sc)
        assert est.feasible, f"{name}: own fleet infeasible ({est.reason})"


def test_registry_unknown_name():
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")


# ------------------------------------------------------------ cluster fidelity
def test_to_cluster_runs_small_disagg_scenario_to_completion():
    sc = get_scenario("ds8b-4xh200-disagg")
    sc = dataclasses.replace(sc, traffic=dataclasses.replace(
        sc.traffic, n_requests=12, rate=20.0))
    rt = sc.to_cluster()
    rt.submit_trace(sc.trace())
    m = rt.run(max_steps=500_000)
    s = m.summary(sc.slo())
    assert s["n_finished"] == 12
    assert s["n_migrations"] == 12      # every request crossed pools
    names = {w.name for w in rt.workers}
    assert names == {"pre0", "dec0", "dec1", "dec2"}


# ------------------------------------------------------- multi-tenant classes
def test_trace_class_tagging_deterministic_and_priority_independent():
    sc = get_scenario("ds8b-4xh200-mixed")
    sc = dataclasses.replace(sc, traffic=dataclasses.replace(
        sc.traffic, n_requests=200))
    t1, t2 = trace(sc), trace(sc)
    assert t1 == t2                                   # deterministic in seed
    names = {e.slo_class for e in t1}
    assert names == {"interactive", "batch"}
    frac = sum(e.slo_class == "interactive" for e in t1) / len(t1)
    assert 0.25 < frac < 0.55                         # ~the 0.4 mix weight
    # tagging depends on the traffic spec only — a class-blind variant
    # (priorities zeroed, no slice) replays the identical tiered trace
    blind = dataclasses.replace(
        sc, slos=tuple(dataclasses.replace(c, priority=0) for c in sc.slos),
        class_kv_headroom=0.0)
    assert trace(blind) == t1
    # single-class scenarios tag everything with their default class
    co = get_scenario("ds8b-4xh200-colocated")
    assert all(e.slo_class == "interactive" for e in trace(co))


def test_class_config_reaches_engines_and_cluster():
    sc = get_scenario("ds8b-4xh200-mixed")
    assert sc.class_priorities() == {"interactive": 10, "batch": 0}
    eng = sc.to_engine()
    classes = eng.sched.admission.classes
    assert classes.priority == {"interactive": 10, "batch": 0}
    assert classes.kv_headroom == pytest.approx(0.10)
    rt = sc.to_cluster()
    for w in rt.workers:
        assert w.engine.sched.admission.classes.priority["interactive"] == 10
    assert rt.cfg.class_priorities == {"interactive": 10, "batch": 0}


def test_mixed_scenario_cluster_run_reports_classes():
    sc = get_scenario("ds8b-4xh200-mixed")
    sc = dataclasses.replace(sc, traffic=dataclasses.replace(
        sc.traffic, n_requests=24, rate=16.0))
    rt = sc.to_cluster()
    rt.submit_trace(sc.trace())
    m = rt.run(max_steps=500_000)
    s = m.summary(slos=sc.slo_map())
    assert s["n_finished"] == 24
    assert set(s["classes"]) == {"interactive", "batch"}
    assert sum(c["n"] for c in s["classes"].values()) == 24
    assert sum(c["goodput_tok_s"] for c in s["classes"].values()) \
        == pytest.approx(s["goodput_tok_s"])
