"""Multi-device parity tests.

jax fixes the device count at first init, so these run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 — the same mechanism the
production dry-run uses.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=540)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_moe_ep_matches_reference():
    """shard_map split & replicated dispatch == single-device reference on a
    2x4 mesh (all_to_all + psum paths)."""
    _run("""
        from repro.configs.base import MoEConfig, ModelConfig
        from repro.models.moe import moe_ffn, moe_ffn_reference
        from repro.parallel.sharding import ParallelContext
        cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                          moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=48,
                                        capacity_factor=8.0))
        m = cfg.moe
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        p = {"router": jax.random.normal(ks[0], (32, 8)) * 0.1,
             "we_gate": jax.random.normal(ks[1], (8, 32, 48)) * 0.1,
             "we_up": jax.random.normal(ks[2], (8, 32, 48)) * 0.1,
             "we_down": jax.random.normal(ks[3], (8, 48, 32)) * 0.1}
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        ref = moe_ffn_reference(x.reshape(-1, 32), p, cfg).reshape(x.shape)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for mode in ("split", "replicated"):
            ctx = ParallelContext(mesh=mesh, fsdp_axis=None, moe_dispatch=mode)
            out = jax.jit(lambda x: moe_ffn(x, p, cfg, ctx, token_axes=None))(x)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=3e-4, atol=3e-4)
        print("moe parity ok")
    """)


def test_sharded_forward_all_families():
    """Every family lowers + runs on a 4x2 mesh with padded heads + FSDP."""
    _run("""
        from repro.configs.registry import get_smoke_config
        from repro.models import transformer as T
        from repro.parallel.sharding import ParallelContext
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        for arch in ["qwen3-14b", "phi3.5-moe-42b-a6.6b", "zamba2-2.7b",
                     "xlstm-350m", "musicgen-medium", "kimi-k2-1t-a32b"]:
            cfg = get_smoke_config(arch)
            ctx = ParallelContext(mesh=mesh)
            p = T.init_params(cfg, jax.random.PRNGKey(0), ctx, mode="train",
                              dtype=jnp.float32)
            p = jax.device_put(p, T.param_shardings(cfg, ctx, mode="train"))
            tok = jax.device_put(
                jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                   cfg.vocab),
                NamedSharding(mesh, P("data", None)))
            out = jax.jit(lambda p, t: T.forward(p, t, cfg, ctx,
                                                 mode="train")[0])(p, tok)
            assert bool(jnp.isfinite(out).all()), arch
        print("sharded families ok")
    """)


def test_pipeline_equivalence():
    _run("""
        from repro.parallel.pipeline import pipeline_forward
        mesh = jax.make_mesh((4,), ("stage",))
        W = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 32)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        stage = lambda w, xm: jnp.tanh(xm @ w)
        out = pipeline_forward(stage, W, x, mesh=mesh, n_micro=4)
        ref = x
        for i in range(4):
            ref = jnp.tanh(ref @ W[i])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        g = jax.grad(lambda W: pipeline_forward(stage, W, x, mesh=mesh,
                                                n_micro=2).sum())(W)
        gr = jax.grad(lambda W: jnp.tanh(jnp.tanh(jnp.tanh(jnp.tanh(
            x @ W[0]) @ W[1]) @ W[2]) @ W[3]).sum())(W)
        np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                                   rtol=1e-4, atol=1e-5)
        print("pipeline ok")
    """)


def test_train_step_sharded_with_zero_sharded_optimizer():
    _run("""
        from repro.configs.registry import get_smoke_config
        from repro.models import transformer as T
        from repro.parallel.sharding import ParallelContext
        from repro.train.optimizer import AdamWConfig, init_opt_state, \\
            opt_state_shardings
        from repro.train.train_step import make_train_step
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_smoke_config("llama3.2-3b")
        ctx = ParallelContext(mesh=mesh, remat="full")
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=1)
        p = T.init_params(cfg, jax.random.PRNGKey(0), ctx, mode="train",
                          dtype=jnp.float32)
        psh = T.param_shardings(cfg, ctx, mode="train")
        p = jax.device_put(p, psh)
        opt = jax.device_put(init_opt_state(p, ocfg),
                             opt_state_shardings(psh, mesh))
        tok = jax.device_put(
            jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab),
            NamedSharding(mesh, P("data", None)))
        step = jax.jit(make_train_step(cfg, ctx, ocfg))
        p2, opt2, m = step(p, opt, {"tokens": tok, "labels": tok})
        assert bool(jnp.isfinite(m["loss"])), m
        # optimizer moments share the parameter sharding (ZeRO)
        wq = p2["dense_stack"]["wq"]
        mq = opt2["m"]["dense_stack"]["wq"]
        assert wq.sharding == mq.sharding
        print("sharded train ok", float(m["loss"]))
    """)


def test_elastic_checkpoint_restore_across_meshes():
    """A checkpoint written from a single-device run restores onto an 8-device
    mesh with the new shardings (elastic restart)."""
    _run("""
        import tempfile
        from repro.configs.registry import get_smoke_config
        from repro.models import transformer as T
        from repro.parallel.sharding import ParallelContext, single_device_ctx
        from repro.train import checkpoint as ckpt
        cfg = get_smoke_config("llama3.2-3b")
        # writer: single device, tp=1 layout is the (4,2)-mesh layout too —
        # use the SAME ctx family (padded for tp=2) so structures match
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ctx = ParallelContext(mesh=mesh)
        p = T.init_params(cfg, jax.random.PRNGKey(0), ctx, mode="train",
                          dtype=jnp.float32)
        d = tempfile.mkdtemp()
        ckpt.save(p, d, step=3)
        # reader: different mesh shape (2, 4) — elastic re-shard on restore
        mesh2 = jax.make_mesh((2, 4), ("data", "model"))
        ctx2 = ParallelContext(mesh=mesh2)
        # same padded head count needed for identical param STRUCTURE:
        # tp=2 vs tp=4 both pad 24->24? llama3.2 smoke heads=4, kv=2:
        # tp=2 -> hp=4, tp=4 -> hp=4: structures match
        sh2 = T.param_shardings(cfg, ctx2, mode="train")
        restored, step = ckpt.restore(p, d, shardings=sh2)
        assert step == 3
        wq = restored["dense_stack"]["wq"]
        assert wq.sharding.mesh.shape == {"data": 2, "model": 4}
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(wq)),
            np.asarray(jax.device_get(p["dense_stack"]["wq"])))
        print("elastic restore ok")
    """)
