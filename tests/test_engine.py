"""End-to-end engine behaviour: real-execution correctness (engine output ==
straight-line greedy decode, WITH and WITHOUT forced preemption), sim-mode
capacity-trap dynamics, autotuner, and DP routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core import perf_model as pm
from repro.core.engine import EngineConfig, InferenceEngine
from repro.core.router import DPRouter, RouterConfig
from repro.core.runner import JaxRunner, SimRunner
from repro.models import transformer as T
from repro.parallel.sharding import single_device_ctx

CTX = single_device_ctx()


def _greedy_reference(cfg, params, prompt, n_new):
    tokens = jnp.asarray([prompt], jnp.int32)
    last, state = T.prefill(params, tokens, cfg, CTX, max_len=192,
                            cache_dtype=jnp.float32)
    out = [int(jnp.argmax(last[0]))]
    for _ in range(n_new - 1):
        logits, state = T.decode_step(
            params, state, jnp.asarray([[out[-1]]], jnp.int32), cfg, CTX)
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke_config("llama3.2-3b")
    params = T.init_params(cfg, jax.random.PRNGKey(0), CTX, mode="serve",
                           dtype=jnp.float32)
    return cfg, params


def _run_engine(cfg, params, prompts, n_new, n_pages):
    runner = JaxRunner(cfg, params, CTX, max_slots=4, max_len=192)
    ecfg = EngineConfig(n_pages=n_pages, max_num_seqs=4,
                        max_num_batched_tokens=512, chunk_size=192,
                        admission_mode="naive")
    eng = InferenceEngine(cfg, ecfg, runner, virtual_clock=False)
    reqs = [eng.submit(p, n) for p, n in zip(prompts, n_new)]
    eng.run(max_steps=2000)
    return reqs


def test_engine_matches_greedy(small_model):
    cfg, params = small_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).tolist()
               for n in (7, 11, 5)]
    n_new = [6, 4, 8]
    reqs = _run_engine(cfg, params, prompts, n_new, n_pages=64)
    for p, n, r in zip(prompts, n_new, reqs):
        assert r.output == _greedy_reference(cfg, params, p, n)


def test_engine_preemption_preserves_outputs(small_model):
    """With a pool sized to force preemption+recompute, outputs must still be
    exactly the unconstrained greedy continuation (§IV-A recompute path)."""
    cfg, params = small_model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=30).tolist() for _ in range(3)]
    n_new = [20, 20, 20]
    reqs = _run_engine(cfg, params, prompts, n_new, n_pages=7)
    assert sum(r.n_preemptions for r in reqs) > 0, \
        "pool was sized to force preemption"
    for p, n, r in zip(prompts, n_new, reqs):
        assert r.output == _greedy_reference(cfg, params, p, n)


def _sim_engine(cfg, max_seqs, n_pages, admission="naive", autotune=False):
    ecfg = EngineConfig(n_pages=n_pages, max_num_seqs=max_seqs,
                        max_num_batched_tokens=4096, chunk_size=256,
                        admission_mode=admission, autotune=autotune)
    return InferenceEngine(
        cfg, ecfg, SimRunner(cfg, pm.ParallelismPlan(), pm.H200))


def test_sim_capacity_trap_dynamics():
    """Obs 1/2: TTFT falls and TPOT rises with concurrency; oversubscription
    triggers preemption."""
    from repro.configs.paper_models import DS_DISTILL_8B
    cfg = DS_DISTILL_8B
    res = {}
    for ms in (16, 256):
        eng = _sim_engine(cfg, ms, n_pages=3000)
        for _ in range(120):
            eng.submit(100, 600, arrival=0.0)
        s = eng.run(max_steps=50000).summary()
        res[ms] = s
    assert res[256]["ttft_s"]["p50"] < res[16]["ttft_s"]["p50"]
    assert res[256]["tpot_s"]["mean"] > res[16]["tpot_s"]["mean"]
    assert res[256]["preemptions"] > 0
    assert res[16]["preemptions"] == 0


def test_kv_aware_admission_prevents_preemption_in_sim():
    from repro.configs.paper_models import DS_DISTILL_8B
    cfg = DS_DISTILL_8B
    naive = _sim_engine(cfg, 256, 3000, admission="naive")
    aware = _sim_engine(cfg, 256, 3000, admission="kv_aware")
    for eng in (naive, aware):
        for _ in range(120):
            eng.submit(100, 600, arrival=0.0)
    sn = naive.run(max_steps=50000).summary()
    sa = aware.run(max_steps=50000).summary()
    assert sn["preemptions"] > 0
    assert sa["preemptions"] == 0
    assert sa["recomputed_tokens"] == 0


def test_resumed_request_context_len_not_inflated():
    """Regression: completing a recompute-resume used to zero resume_extra
    without folding the regenerated prefix out of prompt_pos, so context_len
    double-counted it — every preempted-then-resumed request held phantom KV
    pages for the rest of its decode (found by the sim sanitizer's
    used <= isl + generated + 1 invariant)."""
    from repro.configs.paper_models import DS_DISTILL_8B
    eng = _sim_engine(DS_DISTILL_8B, 256, 3000, admission="naive")
    from repro.lint.sanitizer import EngineSanitizer
    eng._sanitizer = EngineSanitizer(eng)
    for _ in range(120):
        eng.submit(100, 600, arrival=0.0)
    s = eng.run(max_steps=50000).summary()   # sanitizer checks every step
    assert s["preemptions"] > 0, "pool was sized to force preemption"
    for r in eng.metrics.finished:
        assert r.resume_extra == 0
        assert r.context_len == r.isl + r.generated, vars(r)


def test_autotuner_backs_off():
    from repro.configs.paper_models import DS_DISTILL_8B
    cfg = DS_DISTILL_8B
    eng = _sim_engine(cfg, 512, 2000, admission="naive", autotune=True)
    for _ in range(200):
        eng.submit(100, 500, arrival=0.0)
    eng.run(max_steps=50000)
    assert eng.sched.cfg.max_num_seqs < 512, "autotuner should shed concurrency"


def test_memory_aware_router_balances():
    from repro.configs.paper_models import DS_DISTILL_8B
    cfg = DS_DISTILL_8B
    replicas = [_sim_engine(cfg, 64, 2000) for _ in range(4)]
    router = DPRouter(replicas, RouterConfig(policy="memory_aware"))
    for i in range(160):
        router.submit(100, 400, arrival=0.0)
    counts = [len(e.sched.waiting) + len(e.sched.running) for e in replicas]
    assert max(counts) - min(counts) <= 2, f"imbalanced routing: {counts}"
    router.run_all()
    done = sum(e.metrics.summary()["n_finished"] for e in replicas)
    assert done == 160
