"""End-to-end serving driver (the paper's experiment, miniaturised): serve a
batch of Natural-Reasoning-profile requests through the real engine on a
small model, with KV-aware admission ON vs OFF, and report the §III-D metric
set — then rerun the same comparison at paper scale on the simulator.

    PYTHONPATH=src python examples/serve_reasoning.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import DS_DISTILL_8B
from repro.configs.registry import get_smoke_config
from repro.core import perf_model as pm
from repro.core.engine import EngineConfig, InferenceEngine
from repro.core.runner import JaxRunner, SimRunner
from repro.data.reasoning import REASONING, sample
from repro.models import transformer as T
from repro.parallel.sharding import single_device_ctx


def real_engine_run(admission: str):
    cfg = get_smoke_config("llama3.2-3b")
    ctx = single_device_ctx()
    params = T.init_params(cfg, jax.random.PRNGKey(0), ctx, mode="serve",
                           dtype=jnp.float32)
    runner = JaxRunner(cfg, params, ctx, max_slots=6, max_len=160)
    eng = InferenceEngine(
        cfg, EngineConfig(n_pages=30, max_num_seqs=6,
                          max_num_batched_tokens=1024, chunk_size=160,
                          admission_mode=admission),
        runner, virtual_clock=False)
    rng = np.random.default_rng(0)
    for _ in range(10):
        isl = int(rng.integers(8, 24))
        osl = int(rng.integers(24, 80))          # "reasoning-heavy" tail
        eng.submit(rng.integers(0, cfg.vocab, isl).tolist(), osl)
    return eng.run().summary()


def sim_fleet_run(admission: str):
    cfg = DS_DISTILL_8B
    eng = InferenceEngine(
        cfg,
        EngineConfig(n_pages=pm.kv_capacity_tokens(
            cfg, pm.ParallelismPlan(), pm.H200) // 16,
            max_num_seqs=384, max_num_batched_tokens=8192,
            chunk_size=512, admission_mode=admission),
        SimRunner(cfg, pm.ParallelismPlan(), pm.H200))
    cap = eng.alloc.n_pages * 16
    for isl, osl in sample(REASONING, 400, seed=0):
        eng.submit(int(isl), int(min(osl, 8000, cap - isl - 2)), arrival=0.0)
    return eng.run(max_steps=300_000).summary()


def show(tag, s):
    print(f"  [{tag}] done={s['n_finished']} "
          f"tput={s['gen_throughput_tok_s']:.0f}tok/s "
          f"ttft_p50={s['ttft_s']['p50']:.2f}s "
          f"tpot={s['tpot_s']['mean']*1e3:.1f}ms "
          f"e2e_p95={s['e2e_s']['p95']:.1f}s "
          f"preempt={s['preemptions']} recompute={s['recomputed_tokens']}tok")


def main():
    print("== real execution (reduced model, this host) ==")
    for mode in ("naive", "kv_aware"):
        show(mode, real_engine_run(mode))
    print("== simulated DS-8B on one H200 (paper workload profile) ==")
    for mode in ("naive", "kv_aware"):
        show(mode, sim_fleet_run(mode))
    print("KV-aware admission eliminates the preemption storm (paper Obs 1/8): "
          "higher throughput AND lower tail latency.")


if __name__ == "__main__":
    main()
