"""The paper's decision framework in action, at three fidelities.

Default: compile one registry Scenario three ways — the analytical planner
(ranked plans), a virtual-clock engine replica (real scheduler/allocator
dynamics), and the full cluster runtime (open-loop arrivals, routing, SLOs)
— and report planner-predicted vs simulated decode throughput side by side:

    PYTHONPATH=src python examples/plan_deployment.py \
        --scenario ds8b-4xh200-colocated

Classic mode — rank parallelism plans for any assigned architecture:

    PYTHONPATH=src python examples/plan_deployment.py --arch kimi-k2-1t-a32b \
        --hw v5e --devices 256
"""
import argparse
import sys

from repro.configs.registry import ALL_MODELS, get_config
from repro.core import perf_model as pm, planner
from repro.scenario import SCENARIOS, estimate_fleet, get_scenario


def print_plan_table(ests, k: int = 8):
    print(f"{'plan':>16s} {'est completion':>15s} {'decode tok/s':>13s} "
          f"{'conc/replica':>13s} {'KV cap (tok)':>13s}")
    for e in ests[:k]:
        if e.feasible:
            print(f"{e.label():>16s} {e.completion_s:>14.0f}s "
                  f"{e.decode_tput_tok_s:>13.0f} {e.concurrency:>13d} "
                  f"{e.kv_capacity_tokens:>13d}")
        else:
            print(f"{e.label():>16s}   INFEASIBLE ({e.reason})")


def rank_arch(args):
    cfg = get_config(args.arch)
    hw = {"h200": pm.H200, "v5e": pm.V5E}[args.hw]
    wl = planner.Workload(mean_osl=args.mean_osl)
    ests = planner.plan(cfg, hw, args.devices, wl,
                        dtype_bytes=1 if args.fp8 else 2)
    print(f"{args.arch} on {args.devices}x {hw.name} "
          f"(mean OSL {args.mean_osl:.0f}):")
    print_plan_table(ests)


def three_fidelities(name: str):
    sc = get_scenario(name)
    diags = sc.check()
    if diags:
        for d in diags:
            print(f"preflight: {sc.name}: {d.format()}", file=sys.stderr)
        sys.exit(2)
    print(f"== scenario {sc.name}: {sc.model.name} on {sc.n_devices} devices,"
          f" {sc.traffic.process} traffic ==\n")

    # fidelity 1 — analytical planner over the scenario's device budget
    ests = sc.to_plan()
    print("[1/3] planner (analytic, ~ms):")
    print_plan_table(ests)
    if len(sc.fleet) == 1:
        chosen = estimate_fleet(sc)
        print(f"  scenario's own fleet = {chosen.label()}: "
              f"predicted decode {chosen.decode_tput_tok_s:.0f} tok/s\n")
    else:
        # a disaggregated fleet has no single aggregate plan; compare
        # against the best ranked colocated plan for the same budget
        chosen = next(e for e in ests if e.feasible)
        print(f"  best ranked plan = {chosen.label()}: "
              f"predicted decode {chosen.decode_tput_tok_s:.0f} tok/s "
              f"(disaggregated fleet benchmarked against it)\n")

    # fidelity 2 — one decode-capable virtual-clock replica, closed loop
    # (capacity measure; prefill-only groups can't decode the workload)
    gi = next(i for i, g in enumerate(sc.fleet) if g.role != "prefill")
    g = sc.fleet[gi]
    eng = sc.to_engine(group=gi)
    entries = sc.trace()
    share = entries[::g.count]            # this replica's round-robin share
    for e in share:
        eng.submit(e.isl, e.osl, arrival=0.0)
    s = eng.run(max_steps=2_000_000).summary()
    sim_fleet = s["gen_throughput_tok_s"] * g.count
    print(f"[2/3] engine sim (1 {g.role} replica, closed loop, "
          f"{len(share)} reqs): {s['gen_throughput_tok_s']:.0f} tok/s/replica "
          f"-> x{g.count} = {sim_fleet:.0f} tok/s fleet\n")

    # fidelity 3 — the full fleet under open-loop arrivals and SLOs
    rt = sc.to_cluster()
    rt.submit_trace(entries)
    m = rt.run()
    slo = sc.slo()
    cs = m.summary(slo)
    print(f"[3/3] cluster sim ({len(rt.workers)} workers, "
          f"{sc.traffic.process} arrivals): "
          f"{cs['throughput_tok_s']:.0f} tok/s delivered"
          + (f", goodput {cs['goodput_tok_s']:.0f} tok/s "
             f"(SLO attainment {cs['slo_attainment']:.2f})"
             if slo is not None else ""))

    print(f"\ndecode throughput, side by side (tok/s, fleet):")
    print(f"  planner predicted : {chosen.decode_tput_tok_s:>8.0f}  "
          "(steady-state capacity)")
    print(f"  engine simulated  : {sim_fleet:>8.0f}  "
          "(closed-loop, real batching/preemption)")
    print(f"  cluster simulated : {cs['throughput_tok_s']:>8.0f}  "
          "(open-loop arrivals — delivered, not capacity)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="ds8b-4xh200-colocated",
                    choices=sorted(SCENARIOS))
    ap.add_argument("--arch", default=None, choices=sorted(ALL_MODELS),
                    help="classic mode: rank plans for an architecture")
    ap.add_argument("--hw", choices=["h200", "v5e"], default="v5e")
    ap.add_argument("--devices", type=int, default=256)
    ap.add_argument("--mean-osl", type=float, default=6800.0)
    ap.add_argument("--fp8", action="store_true", help="fp8 weights")
    args = ap.parse_args()

    if args.arch:
        rank_arch(args)
    else:
        classic_flags_used = (args.hw != "v5e" or args.devices != 256
                              or args.mean_osl != 6800.0 or args.fp8)
        if classic_flags_used:
            ap.error("--hw/--devices/--mean-osl/--fp8 only apply to classic "
                     "mode; pass --arch as well (scenario mode takes these "
                     "from the spec)")
        three_fidelities(args.scenario)


if __name__ == "__main__":
    main()
