"""The paper's decision framework in action: rank parallelism plans for any
assigned architecture on H200 nodes or v5e pod slices.

    PYTHONPATH=src python examples/plan_deployment.py --arch kimi-k2-1t-a32b \
        --hw v5e --devices 256
"""
import argparse

from repro.configs.paper_models import PAPER_MODELS
from repro.configs.registry import ALL_MODELS, get_config
from repro.core import perf_model as pm, planner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-405b",
                    choices=sorted(ALL_MODELS))
    ap.add_argument("--hw", choices=["h200", "v5e"], default="v5e")
    ap.add_argument("--devices", type=int, default=256)
    ap.add_argument("--mean-osl", type=float, default=6800.0)
    ap.add_argument("--fp8", action="store_true", help="fp8 weights")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    hw = {"h200": pm.H200, "v5e": pm.V5E}[args.hw]
    wl = planner.Workload(mean_osl=args.mean_osl)
    ests = planner.plan(cfg, hw, args.devices, wl,
                        dtype_bytes=1 if args.fp8 else 2)
    print(f"{args.arch} on {args.devices}x {hw.name} "
          f"(mean OSL {args.mean_osl:.0f}):")
    print(f"{'plan':>16s} {'est completion':>15s} {'decode tok/s':>13s} "
          f"{'conc/replica':>13s} {'KV cap (tok)':>13s}")
    for e in ests[:8]:
        if e.feasible:
            print(f"{e.label():>16s} {e.completion_s:>14.0f}s "
                  f"{e.decode_tput_tok_s:>13.0f} {e.concurrency:>13d} "
                  f"{e.kv_capacity_tokens:>13d}")
        else:
            print(f"{e.label():>16s}   INFEASIBLE ({e.reason})")


if __name__ == "__main__":
    main()
