"""Cluster serving demo: one fleet, two architectures.

Replays the same open-loop Natural-Reasoning trace through (a) 4 colocated
DP replicas and (b) a disaggregated 1-prefill + 3-decode fleet with modeled
KV-transfer migration, and prints the SLO-goodput comparison plus each
replica's KV-saturation trajectory.

    PYTHONPATH=src python examples/serve_cluster.py
"""
from repro.configs.paper_models import DS_DISTILL_8B
from repro.core import perf_model as pm
from repro.core.metrics import SLO
from repro.cluster import (ClusterConfig, ClusterRuntime, PoissonProcess,
                           make_trace, make_sim_worker)
from repro.data.reasoning import LONG_REASONING

RATE = 12.0          # req/s — past the colocated fleet's capacity knee
N = 150
SLO_TARGET = SLO(ttft_s=0.5, tpot_s=0.020)


def build(mode: str):
    cfg, plan = DS_DISTILL_8B, pm.ParallelismPlan()
    kw = dict(n_pages=3000, max_seqs=64)
    if mode == "colocated":
        ws = [make_sim_worker(cfg, plan, role="colocated", name=f"co{i}",
                              **kw) for i in range(4)]
    else:
        ws = [make_sim_worker(cfg, plan, role="prefill", name="pre0", **kw)]
        ws += [make_sim_worker(cfg, plan, role="decode", name=f"dec{i}",
                               **kw) for i in range(3)]
    return ClusterRuntime(ws, ClusterConfig())


def main():
    trace = make_trace(PoissonProcess(rate=RATE), LONG_REASONING, N,
                       seed=42, osl_cap=1200)
    print(f"== {N} long-context reasoning requests, Poisson {RATE:.0f} req/s,"
          f" DS-8B on 4xH200 (sim) ==")
    for mode in ("colocated", "disaggregated"):
        rt = build(mode)
        rt.submit_trace(trace)
        m = rt.run()
        s = m.summary(SLO_TARGET)
        r = m.request_summary()
        print(f"\n[{mode}] finished={s['n_finished']} "
              f"goodput={s['goodput_tok_s']:.0f}tok/s "
              f"(throughput={s['throughput_tok_s']:.0f}) "
              f"slo_attainment={s['slo_attainment']:.2f}")
        print(f"  ttft p95={r['ttft_s']['p95']*1e3:.0f}ms "
              f"tpot p95={r['tpot_s']['p95']*1e3:.1f}ms "
              f"migrations={s['n_migrations']} "
              f"(mean transfer {s['mean_transfer_s']*1e3:.2f}ms)")
        for name, w in s["workers"].items():
            sat = w["time_to_saturation_s"]
            print(f"  {name:6s} [{w['role']:9s}] peak_kv={w['peak_kv_util']:.2f} "
                  f"preempt={w['preemptions']:3d} "
                  + (f"saturated@{sat:.1f}s" if sat is not None
                     else "never saturated"))
    print("\nPast the capacity knee the colocated fleet queues arrivals "
          "behind saturated KV pools (TTFT blows the SLO); the disaggregated "
          "fleet keeps TTFT flat and holds more goodput (paper Obs 1/3/4).")


if __name__ == "__main__":
    main()
