"""Cluster serving demo: one scenario pair, two fleet shapes.

Replays the registry's `ds8b-4xh200-colocated` / `ds8b-4xh200-disagg`
scenarios — identical model, devices, traffic and SLO; only the fleet shape
differs — and prints the SLO-goodput comparison plus each replica's
KV-saturation trajectory. Fleets are built exclusively by
``Scenario.to_cluster()``.

    PYTHONPATH=src python examples/serve_cluster.py
"""
from repro.scenario import get_scenario

PAIR = ("ds8b-4xh200-colocated", "ds8b-4xh200-disagg")


def main():
    base = get_scenario(PAIR[0])
    trace = base.trace()          # same trace for both fleets (same seed)
    slo = base.slo("interactive")
    print(f"== {base.traffic.n_requests} long-context reasoning requests, "
          f"Poisson {base.traffic.rate:.0f} req/s, {base.model.name} on "
          f"{base.n_devices}xH200 (sim) ==")
    for name in PAIR:
        sc = get_scenario(name)
        mode = "disaggregated" if sc.disaggregated else "colocated"
        rt = sc.to_cluster()
        rt.submit_trace(trace)
        m = rt.run()
        s = m.summary(slo)
        r = m.request_summary()
        print(f"\n[{mode}] finished={s['n_finished']} "
              f"goodput={s['goodput_tok_s']:.0f}tok/s "
              f"(throughput={s['throughput_tok_s']:.0f}) "
              f"slo_attainment={s['slo_attainment']:.2f}")
        print(f"  ttft p95={r['ttft_s']['p95']*1e3:.0f}ms "
              f"tpot p95={r['tpot_s']['p95']*1e3:.1f}ms "
              f"migrations={s['n_migrations']} "
              f"(mean transfer {s['mean_transfer_s']*1e3:.2f}ms)")
        for wname, w in s["workers"].items():
            sat = w["time_to_saturation_s"]
            print(f"  {wname:6s} [{w['role']:9s}] "
                  f"peak_kv={w['peak_kv_util']:.2f} "
                  f"preempt={w['preemptions']:3d} "
                  + (f"saturated@{sat:.1f}s" if sat is not None
                     else "never saturated"))
    print("\nPast the capacity knee the colocated fleet queues arrivals "
          "behind saturated KV pools (TTFT blows the SLO); the disaggregated "
          "fleet keeps TTFT flat and holds more goodput (paper Obs 1/3/4).")


if __name__ == "__main__":
    main()
