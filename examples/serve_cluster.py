"""Cluster serving demo: one scenario pair, two fleet shapes, plus tiers.

Replays the registry's `ds8b-4xh200-colocated` / `ds8b-4xh200-disagg`
scenarios — identical model, devices, traffic and SLO; only the fleet shape
differs — and prints the SLO-goodput comparison plus each replica's
KV-saturation trajectory, then runs the `ds8b-4xh200-mixed` multi-tenant
scenario and prints the per-class (interactive vs batch) breakdown, and
finally the `ds8b-autoscale-diurnal` elastic scenario with its scaling
timeline (replica joins/retires with timestamps). Fleets are built
exclusively by ``Scenario.to_cluster()``; goodput uses the corrected
accounting (fleet-makespan denominator, unfinished-as-miss).

The colocated/disagg pair also runs under a ``repro.obs`` tap (a pure
event-stream subscriber — docs/obs.md): each fleet's summary line carries
the bottleneck-regime attribution, and the full report is a
``--report`` flag away.

    PYTHONPATH=src python examples/serve_cluster.py [--report]
"""
import dataclasses
import sys

from repro.obs import attach, regime_fractions, render_text
from repro.scenario import get_scenario

PAIR = ("ds8b-4xh200-colocated", "ds8b-4xh200-disagg")
MIXED = "ds8b-4xh200-mixed"
ELASTIC = "ds8b-autoscale-diurnal"


def preflight(sc):
    """Refuse to demo a spec whose static feasibility check errors out."""
    diags = sc.check()
    if diags:
        for d in diags:
            print(f"preflight: {sc.name}: {d.format()}", file=sys.stderr)
        sys.exit(2)
    return sc


def show_fleet(s, r):
    print(f"  ttft p95={r['ttft_s']['p95']*1e3:.0f}ms "
          f"tpot p95={r['tpot_s']['p95']*1e3:.1f}ms "
          f"migrations={s['n_migrations']} "
          f"(mean transfer {s['mean_transfer_s']*1e3:.2f}ms)")
    for wname, w in s["workers"].items():
        sat = w["time_to_saturation_s"]
        print(f"  {wname:6s} [{w['role']:9s}] "
              f"peak_kv={w['peak_kv_util']:.2f} "
              f"preempt={w['preemptions']:3d} "
              + (f"saturated@{sat:.1f}s" if sat is not None
                 else "never saturated"))


def main():
    for name in (*PAIR, MIXED, ELASTIC):
        preflight(get_scenario(name))
    base = get_scenario(PAIR[0])
    trace = base.trace()          # same trace for both fleets (same seed)
    slo = base.slo("interactive")
    print(f"== {base.traffic.n_requests} long-context reasoning requests, "
          f"Poisson {base.traffic.rate:.0f} req/s, {base.model.name} on "
          f"{base.n_devices}xH200 (sim) ==")
    want_report = "--report" in sys.argv[1:]
    for name in PAIR:
        sc = get_scenario(name)
        mode = "disaggregated" if sc.disaggregated else "colocated"
        rt = sc.to_cluster()
        build = attach(rt.events)     # obs tap: subscriber, metrics untouched
        rt.submit_trace(trace)
        m = rt.run()
        rep = build()
        s = m.summary(slo, regimes=regime_fractions(rep))
        print(f"\n[{mode}] finished={s['n_finished']}/{s['n_submitted']} "
              f"goodput={s['goodput_tok_s']:.0f}tok/s "
              f"(throughput={s['throughput_tok_s']:.0f}) "
              f"slo_attainment={s['slo_attainment']:.2f} "
              f"regime={s['regimes']['dominant']}")
        show_fleet(s, m.request_summary())
        if want_report:
            print(render_text(rep, title=name))
    print("\nPast the capacity knee the colocated fleet queues arrivals "
          "behind saturated KV pools (TTFT blows the SLO); the disaggregated "
          "fleet keeps TTFT flat and holds more goodput (paper Obs 1/3/4).")

    # ---- multi-tenant SLO classes on one fleet ----------------------------
    sc = get_scenario(MIXED)
    mix = dict(sc.traffic.class_mix)
    print(f"\n== mixed tenancy: {sc.traffic.n_requests} requests, "
          f"{mix['interactive']:.0%} interactive / {mix['batch']:.0%} batch, "
          f"Poisson {sc.traffic.rate:.0f} req/s, KV slice "
          f"{sc.class_kv_headroom:.0%} ==")
    rt = sc.to_cluster()
    rt.submit_trace(sc.trace())
    m = rt.run()
    s = m.summary(slos=sc.slo_map())
    print(f"[mixed] finished={s['n_finished']}/{s['n_submitted']} "
          f"fleet goodput={s['goodput_tok_s']:.0f}tok/s "
          f"attainment={s['slo_attainment']:.2f}")
    for cname, c in s["classes"].items():
        print(f"  {cname:12s} n={c['n']:3d} "
              f"attainment={c['slo_attainment']:.2f} "
              f"goodput={c['goodput_tok_s']:.0f}tok/s")
    print("Interactive requests jump waiting queues and keep a KV headroom "
          "slice; batch absorbs the backpressure (benchmarks/slo_tiers.py "
          "sweeps this against a class-blind baseline).")

    # ---- elastic autoscaling under diurnal load ---------------------------
    sc = get_scenario(ELASTIC)
    a = sc.autoscaler
    print(f"\n== elastic fleet: {sc.traffic.n_requests} requests on a "
          f"piecewise-rate day {sc.traffic.phases}, {a.policy} controller, "
          f"bounds [{a.min_workers}, {a.max_workers}] ==")
    rt = sc.to_cluster()
    rt.submit_trace(sc.trace())
    m = rt.run()
    s = m.summary(slo=sc.slo())
    print(f"[auto] finished={s['n_finished']}/{s['n_submitted']} "
          f"attainment={s['slo_attainment']:.2f} "
          f"goodput/worker-s={s['goodput_tok_per_worker_s']:.0f} "
          f"worker-seconds={s['worker_seconds']:.0f}")
    print("scaling timeline:")
    for e in m.scaling_events:
        print(f"  t={e.t:6.2f}s {e.kind:9s} {e.worker:6s} "
              f"[{e.role}] pool={e.pool_size}")
    # the peak-provisioned static fleet, for the worker-second comparison
    peak = dataclasses.replace(
        sc, autoscaler=None,
        fleet=(dataclasses.replace(sc.fleet[0], count=a.max_workers),))
    rt2 = peak.to_cluster()
    rt2.submit_trace(peak.trace())
    s2 = rt2.run().summary(slo=peak.slo())
    print(f"[peak-static x{a.max_workers}] "
          f"attainment={s2['slo_attainment']:.2f} "
          f"goodput/worker-s={s2['goodput_tok_per_worker_s']:.0f} "
          f"worker-seconds={s2['worker_seconds']:.0f}")
    ratio = s["goodput_tok_per_worker_s"] \
        / max(s2["goodput_tok_per_worker_s"], 1e-9)
    print(f"The controller rides the 5x swing: same attainment at "
          f"{ratio:.2f}x the peak fleet's goodput per worker-second "
          f"(benchmarks/autoscale.py asserts the claims).")


if __name__ == "__main__":
    main()
