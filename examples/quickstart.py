"""Quickstart: build a model, run a forward pass, serve a few requests, and
ask the planner how to deploy the full-size version.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_smoke_config
from repro.core import perf_model as pm, planner
from repro.core.engine import EngineConfig, InferenceEngine
from repro.core.runner import JaxRunner
from repro.models import transformer as T
from repro.parallel.sharding import single_device_ctx


def main():
    # 1) a reduced llama3.2-style model, runnable on this host --------------
    cfg = get_smoke_config("llama3.2-3b")
    ctx = single_device_ctx()
    params = T.init_params(cfg, jax.random.PRNGKey(0), ctx, mode="serve",
                           dtype=jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
    logits, _ = T.forward(params, tokens, cfg, ctx, mode="serve")
    print(f"[1] forward: logits {logits.shape}, "
          f"finite={bool(jnp.isfinite(logits).all())}")

    # 2) serve a few requests through the continuous-batching engine --------
    runner = JaxRunner(cfg, params, ctx, max_slots=4, max_len=96)
    eng = InferenceEngine(
        cfg, EngineConfig(n_pages=24, max_num_seqs=4,
                          max_num_batched_tokens=512, chunk_size=96),
        runner, virtual_clock=False)
    for i in range(5):
        prompt = jax.random.randint(jax.random.PRNGKey(i), (8,), 0,
                                    cfg.vocab).tolist()
        eng.submit(prompt, max_new_tokens=8)
    summary = eng.run().summary()
    print(f"[2] engine: {summary['n_finished']} requests, "
          f"{summary['gen_tokens']} tokens, "
          f"preemptions={summary['preemptions']}")

    # 3) plan the full-size deployment on a v5e pod slice --------------------
    full = get_config("llama3.2-3b")
    best = planner.best(full, pm.V5E, 64)
    print(f"[3] planner: llama3.2-3b on 64x v5e -> {best.label()} "
          f"(~{best.decode_tput_tok_s:.0f} decode tok/s, "
          f"{best.concurrency} concurrent reqs/replica)")


if __name__ == "__main__":
    main()
