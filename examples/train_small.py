"""Train a ~100M-param llama-family model for a few hundred steps on CPU with
checkpointing — the end-to-end training driver.

    PYTHONPATH=src python examples/train_small.py --steps 300
(defaults to 60 steps so the example finishes quickly; pass --steps 300 for
the full run)
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.parallel.sharding import single_device_ctx
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step

# ~100M params: 12L x 512d x 8H, 16k vocab
CFG_100M = ModelConfig(name="llama-100m", family="dense", n_layers=12,
                       d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
                       d_ff=1536, vocab=16384, attention="full",
                       rope_theta=10000.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_small")
    args = ap.parse_args()

    cfg = CFG_100M
    ctx = single_device_ctx()
    n = cfg.param_count()
    print(f"model: {n/1e6:.0f}M params")
    ocfg = AdamWConfig(lr=6e-4, warmup_steps=20)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key, ctx, mode="train", dtype=jnp.float32)
    opt = init_opt_state(params, ocfg)
    step_fn = jax.jit(make_train_step(cfg, ctx, ocfg))

    # synthetic data with learnable structure (bigram-ish) so loss falls
    def batch_for(step):
        k = jax.random.fold_in(key, step)
        base = jax.random.randint(k, (args.batch, args.seq + 1), 0, 256)
        toks = (base * 17 + jnp.cumsum(base, axis=1) % 101) % cfg.vocab
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    first = last = None
    t0 = time.time()
    for step in range(args.steps):
        params, opt, m = step_fn(params, opt, batch_for(step))
        if step == 0:
            first = float(m["loss"])
        if (step + 1) % 20 == 0:
            print(f"step {step+1:4d} loss {float(m['loss']):.4f} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)", flush=True)
        if (step + 1) % 50 == 0:
            ckpt.save((params, opt), args.ckpt, step + 1)
    last = float(m["loss"])
    print(f"loss: {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
