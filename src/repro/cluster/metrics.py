"""Fleet-level metric aggregation: SLO attainment, goodput, and per-replica
KV-saturation timelines (the paper's serving-level claims — Obs 3/4: the
fleet's tail is set by the first replica to saturate its KV pool).

Accounting is makespan-honest: the runtime stamps ``t_end`` (the fleet clock
at drain) so ``duration_s`` covers the whole serving window — not just the
finished-request span, which shrinks while the tail is still in flight and
inflates goodput. Submitted-but-unfinished requests count as SLO misses
("tokens served outside the SLO are throughput, not goodput" — and a request
that never finished served them outside any SLO). ``summary(slos=...)``
reports each SLO class against its own targets; per-class goodputs sum to the
fleet goodput."""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List, Mapping, Optional, Union

from repro.core.metrics import (SLO, SLOMap, class_slo_summary,
                                finished_window_s, latency_stats)
from repro.core.request import Request
from repro.cluster.worker import Worker


@dataclasses.dataclass(frozen=True)
class ScalingEvent:
    """One autoscaling lifecycle transition, stamped on the fleet clock.

    kinds: ``scale_up`` (replica minted, cold start begins), ``join``
    (weight load done, entered the route/dispatch pools), ``retire``
    (left the pools, draining in-flight work), ``drained`` (went dark;
    ``Worker.t_retire`` stamped)."""
    t: float
    kind: str
    worker: str
    role: str
    pool_size: int                # active pool size AFTER the transition


@dataclasses.dataclass
class MigrationRecord:
    rid: int
    src: str                      # prefill worker name
    dst: str                      # decode worker name
    t_eject: float
    t_ready: float                # eject + modeled KV-transfer time
    t_delivered: float            # when the decode worker adopted it
    context_tokens: int

    @property
    def transfer_s(self) -> float:
        return self.t_ready - self.t_eject


class ClusterMetrics:
    """Aggregates per-worker MetricsLog + cluster-level migration records —
    derived purely from the fleet event stream (``repro.trace``).

    The runtime subscribes this object to its fleet ``EventLog`` at
    construction; every record here is a fold over that stream: ``arrival``
    grows the routed-request list, ``mint``/``join``/``retire``/``drained``
    become :class:`ScalingEvent` rows, a ``kv_transfer`` paired with its
    adopter's ``inject`` closes a :class:`MigrationRecord`, and ``run_end``
    stamps the fleet makespan. Nothing else may mutate this state (lint
    REP009). ``submitted`` is shared by reference with the runtime, so
    callers holding either see the same list."""

    # stream lifecycle kind -> ScalingEvent kind ("mint" is recorded as the
    # historical "scale_up" so scaling_events stay identical to the
    # pre-stream accounting)
    _SCALING_KINDS = {"mint": "scale_up", "join": "join",
                      "retire": "retire", "drained": "drained"}

    def __init__(self, workers: List[Worker],
                 submitted: Optional[List[Request]] = None):
        self.workers = workers
        self.migrations: List[MigrationRecord] = []
        self.scaling_events: List[ScalingEvent] = []
        self.submitted: List[Request] = submitted if submitted is not None \
            else []
        self.t_end: Optional[float] = None
        # stream-derived lifecycle stamps; workers present at t=0 (never
        # minted/drained on-stream) fall back to their Worker fields
        self._t_join: Dict[str, float] = {}
        self._t_retire: Dict[str, float] = {}
        self._pending_transfers: Dict[int, tuple] = {}

    # ---- the one mutation path: the fleet event stream -------------------
    def on_event(self, ev):
        kind = ev.kind
        if kind == "arrival":
            self.submitted.append(ev.ref)
        elif kind in self._SCALING_KINDS:
            self.scaling_events.append(ScalingEvent(
                t=ev.t, kind=self._SCALING_KINDS[kind], worker=ev.worker,
                role=ev.payload["role"], pool_size=ev.payload["pool_size"]))
            if kind == "mint":
                self._t_join[ev.worker] = ev.t
            elif kind == "drained":
                self._t_retire[ev.worker] = ev.t
        elif kind == "kv_transfer":
            self._pending_transfers[ev.rid] = (
                ev.worker, ev.t, ev.payload["ready"])
        elif kind == "inject" and ev.rid in self._pending_transfers:
            src, t_eject, t_ready = self._pending_transfers.pop(ev.rid)
            self.migrations.append(MigrationRecord(
                rid=ev.rid, src=src, dst=ev.worker,
                t_eject=t_eject, t_ready=t_ready, t_delivered=ev.t,
                context_tokens=ev.payload["context_tokens"]))
        elif kind == "run_end":
            self.t_end = ev.t

    # ------------------------------------------------------------- collection
    def _join_t(self, w: Worker) -> float:
        return self._t_join.get(w.name, w.t_join)

    def _retire_t(self, w: Worker) -> Optional[float]:
        return self._t_retire.get(w.name, w.t_retire)

    def finished_requests(self) -> List[Request]:
        return [r for w in self.workers for r in w.engine.metrics.finished]

    def saturation_timeline(self, worker: Worker) -> List[Dict[str, float]]:
        return [{"t": p.t, "kv_util": p.kv_util}
                for p in worker.engine.metrics.timeline]

    def time_to_saturation(self, worker: Worker,
                           threshold: float = 0.95) -> Optional[float]:
        """First time the worker's KV pool crossed `threshold` utilisation."""
        for p in worker.engine.metrics.timeline:
            if p.kv_util >= threshold:
                return p.t
        return None

    # -------------------------------------------------------------- summaries
    def _window(self, makespan: Optional[float]):
        """(duration, horizon): duration from the explicit makespan when one
        is known — runtime-stamped ``t_end`` or the caller's override —
        falling back to the finished-only span otherwise (no runtime
        attached). A known makespan doubles as the horizon for counting
        unfinished requests as misses."""
        reqs = self.submitted or self.finished_requests()
        end = makespan if makespan is not None else self.t_end
        if end is None:
            return finished_window_s(reqs), None
        t0 = min((r.arrival for r in reqs), default=0.0)
        return max(end - t0, 1e-9), end

    def worker_seconds(self, makespan: Optional[float] = None) -> float:
        """Total provisioned worker-seconds: each worker's active window
        (mint -> decommission, cold start included) integrated over the
        serving window. A static fleet yields ``n_workers * duration``;
        an autoscaled fleet pays only for the replicas it actually held —
        the denominator that makes elastic and fixed fleets cost-comparable
        (goodput per worker-second)."""
        reqs = self.submitted or self.finished_requests()
        end = makespan if makespan is not None else self.t_end
        t0 = min((r.arrival for r in reqs), default=0.0)
        if end is None:
            end = t0 + finished_window_s(reqs)
        # per-worker slice mirrors Worker.active_window, but over the
        # stream-derived mint/drain stamps
        total = 0.0
        for w in self.workers:
            tr = self._retire_t(w)
            w_end = tr if tr is not None else end
            total += max(min(w_end, end) - max(self._join_t(w), t0), 0.0)
        return total

    def summary(self, slo: Optional[Union[SLO, SLOMap]] = None,
                slos: Optional[SLOMap] = None,
                makespan: Optional[float] = None,
                regimes: Optional[Dict] = None) -> Dict:
        """Fleet summary. Pass a single ``slo`` or a ``slos`` class map for
        SLO accounting (a map adds a per-class breakdown under
        ``"classes"``); ``makespan`` overrides the runtime-stamped fleet
        clock. ``regimes`` (the dict from
        ``repro.obs.report.regime_fractions``) merges bottleneck-regime
        fractions under a ``"regimes"`` key — obs stays a pure stream
        consumer, so the attribution is computed there and *handed in*
        here; omitted, the summary is byte-identical to pre-obs output."""
        finished = self.finished_requests()
        all_reqs = self.submitted or finished
        # served tokens include in-flight requests' partial decodes — the
        # denominator is the whole serving window, so the numerator must
        # cover everything served in it (truncated runs would otherwise
        # understate throughput)
        gen = sum(r.generated for r in all_reqs)
        dur, horizon = self._window(makespan)
        ws = self.worker_seconds(makespan)
        per_worker = {}
        for w in self.workers:
            tl = w.engine.metrics.timeline
            sat = self.time_to_saturation(w)
            per_worker[w.name] = {
                "role": w.role,
                "n_finished": len(w.engine.metrics.finished),
                "peak_kv_util": max((p.kv_util for p in tl), default=0.0),
                "mean_kv_util": statistics.fmean(
                    [p.kv_util for p in tl]) if tl else 0.0,
                "preemptions": w.engine.sched.n_preemptions,
                "time_to_saturation_s": sat,
                "t_join": self._join_t(w),
                "t_retire": self._retire_t(w),
            }
        out = {
            "n_submitted": len(all_reqs),
            "n_finished": len(finished),
            "n_unfinished": len(all_reqs) - len(finished),
            "gen_tokens": gen,
            "duration_s": dur,
            "throughput_tok_s": gen / dur,
            # cost-normalised rates: tokens per provisioned worker-second —
            # the number that makes an autoscaled fleet comparable to a
            # statically peak-provisioned one (same goodput, fewer
            # worker-seconds = the utilization gap recovered)
            "worker_seconds": ws,
            "throughput_tok_per_worker_s": gen / max(ws, 1e-9),
            "n_scaling_events": len(self.scaling_events),
            "n_migrations": len(self.migrations),
            "mean_transfer_s": statistics.fmean(
                [m.transfer_s for m in self.migrations])
            if self.migrations else 0.0,
            "workers": per_worker,
            # fleet tail is set by the FIRST saturating replica (Obs 4)
            "first_saturation_s": min(
                (v["time_to_saturation_s"] for v in per_worker.values()
                 if v["time_to_saturation_s"] is not None), default=None),
        }
        table = slos if slos is not None else slo
        if table is not None:
            pool = all_reqs if horizon is not None else finished
            s = class_slo_summary(pool, table, dur, horizon=horizon)
            out["slo_attainment"] = s["slo_attainment"]
            out["goodput_tok_s"] = s["goodput_tok_s"]
            # good tokens / provisioned worker-seconds (goodput_tok_s is
            # good tokens / duration, so multiply the duration back in)
            out["goodput_tok_per_worker_s"] = \
                s["goodput_tok_s"] * dur / max(ws, 1e-9)
            if isinstance(table, Mapping):
                out["classes"] = s["classes"]
        if regimes is not None:
            out["regimes"] = dict(regimes)
        return out

    def request_summary(self) -> Dict:
        """Latency distributions over all finished requests (fleet-wide)."""
        reqs = self.finished_requests()
        return {
            "ttft_s": latency_stats([r.ttft() for r in reqs]),
            "tpot_s": latency_stats([r.tpot() for r in reqs]),
            "e2e_s": latency_stats([r.e2e() for r in reqs]),
            "waiting_s": latency_stats([r.waiting_time() for r in reqs]),
        }
