"""Fleet-level metric aggregation: SLO attainment, goodput, and per-replica
KV-saturation timelines (the paper's serving-level claims — Obs 3/4: the
fleet's tail is set by the first replica to saturate its KV pool)."""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List, Optional

from repro.core.metrics import SLO, goodput_tok_s, slo_attainment
from repro.core.request import Request
from repro.cluster.worker import Worker


@dataclasses.dataclass
class MigrationRecord:
    rid: int
    src: str                      # prefill worker name
    dst: str                      # decode worker name
    t_eject: float
    t_ready: float                # eject + modeled KV-transfer time
    t_delivered: float            # when the decode worker adopted it
    context_tokens: int

    @property
    def transfer_s(self) -> float:
        return self.t_ready - self.t_eject


class ClusterMetrics:
    """Aggregates per-worker MetricsLog + cluster-level migration records."""

    def __init__(self, workers: List[Worker]):
        self.workers = workers
        self.migrations: List[MigrationRecord] = []

    # ------------------------------------------------------------- collection
    def note_migration(self, rec: MigrationRecord):
        self.migrations.append(rec)

    def finished_requests(self) -> List[Request]:
        return [r for w in self.workers for r in w.engine.metrics.finished]

    def saturation_timeline(self, worker: Worker) -> List[Dict[str, float]]:
        return [{"t": p.t, "kv_util": p.kv_util}
                for p in worker.engine.metrics.timeline]

    def time_to_saturation(self, worker: Worker,
                           threshold: float = 0.95) -> Optional[float]:
        """First time the worker's KV pool crossed `threshold` utilisation."""
        for p in worker.engine.metrics.timeline:
            if p.kv_util >= threshold:
                return p.t
        return None

    # -------------------------------------------------------------- summaries
    def summary(self, slo: Optional[SLO] = None) -> Dict:
        reqs = self.finished_requests()
        gen = sum(r.generated for r in reqs)
        t_end = max((r.t_finished or 0.0 for r in reqs), default=0.0)
        t0 = min((r.arrival for r in reqs), default=0.0)
        dur = max(t_end - t0, 1e-9)
        per_worker = {}
        for w in self.workers:
            tl = w.engine.metrics.timeline
            sat = self.time_to_saturation(w)
            per_worker[w.name] = {
                "role": w.role,
                "n_finished": len(w.engine.metrics.finished),
                "peak_kv_util": max((p.kv_util for p in tl), default=0.0),
                "mean_kv_util": statistics.fmean(
                    [p.kv_util for p in tl]) if tl else 0.0,
                "preemptions": w.engine.sched.n_preemptions,
                "time_to_saturation_s": sat,
            }
        out = {
            "n_finished": len(reqs),
            "gen_tokens": gen,
            "duration_s": dur,
            "throughput_tok_s": gen / dur,
            "n_migrations": len(self.migrations),
            "mean_transfer_s": statistics.fmean(
                [m.transfer_s for m in self.migrations])
            if self.migrations else 0.0,
            "workers": per_worker,
            # fleet tail is set by the FIRST saturating replica (Obs 4)
            "first_saturation_s": min(
                (v["time_to_saturation_s"] for v in per_worker.values()
                 if v["time_to_saturation_s"] is not None), default=None),
        }
        if slo is not None:
            out["slo_attainment"] = slo_attainment(reqs, slo)
            out["goodput_tok_s"] = goodput_tok_s(reqs, slo, dur)
        return out

    def request_summary(self) -> Dict:
        """Latency distributions over all finished requests (fleet-wide)."""
        reqs = self.finished_requests()

        def stats(vals):
            vals = sorted(v for v in vals if v is not None)
            if not vals:
                return {"mean": 0.0, "p50": 0.0, "p95": 0.0, "max": 0.0}
            return {"mean": statistics.fmean(vals),
                    "p50": vals[len(vals) // 2],
                    "p95": vals[min(int(len(vals) * 0.95), len(vals) - 1)],
                    "max": vals[-1]}
        return {
            "ttft_s": stats([r.ttft() for r in reqs]),
            "tpot_s": stats([r.tpot() for r in reqs]),
            "e2e_s": stats([r.e2e() for r in reqs]),
            "waiting_s": stats([r.waiting_time() for r in reqs]),
        }
