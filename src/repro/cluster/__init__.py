"""Cluster serving layer: open-loop arrival replay, disaggregated
prefill/decode pools, pluggable routing, elastic autoscaling, and
SLO-goodput accounting."""
from repro.cluster.arrivals import (ArrivalProcess, GammaProcess,
                                    PiecewiseRateProcess, PoissonProcess,
                                    TraceEntry, TraceProcess, assign_classes,
                                    load_trace, make_trace, save_trace)
from repro.cluster.autoscale import (AutoscaleController, AutoscalePolicy,
                                     ScalingSignals, SLOGuard,
                                     TargetUtilization, make_autoscale_policy,
                                     make_autoscaler)
from repro.cluster.metrics import (ClusterMetrics, MigrationRecord,
                                   ScalingEvent)
from repro.cluster.policies import (DispatchPolicy, JoinShortestQueue,
                                    LeastKVHeadroom, MemoryAware,
                                    MostKVHeadroom, RoundRobin, RoutingPolicy,
                                    make_dispatcher, make_policy)
from repro.cluster.rebalance import (KVPressureRebalancer, RebalancePolicy,
                                     make_rebalancer)
from repro.cluster.runtime import ClusterConfig, ClusterRuntime
from repro.cluster.view import (FleetView, NoFeasibleWorker, RebalanceDecision,
                                RequestView, StragglerTracker, WorkerView,
                                eligible_indices, fleet_snapshot, snapshot)
from repro.cluster.worker import Worker, make_sim_worker

__all__ = [
    "ArrivalProcess", "PoissonProcess", "GammaProcess", "TraceProcess",
    "PiecewiseRateProcess",
    "TraceEntry", "make_trace", "assign_classes", "save_trace", "load_trace",
    "ClusterMetrics", "MigrationRecord", "ScalingEvent",
    "ScalingSignals", "AutoscalePolicy", "TargetUtilization", "SLOGuard",
    "AutoscaleController", "make_autoscale_policy", "make_autoscaler",
    "RoutingPolicy", "RoundRobin", "JoinShortestQueue", "MemoryAware",
    "DispatchPolicy", "LeastKVHeadroom", "MostKVHeadroom",
    "make_policy", "make_dispatcher",
    "RebalancePolicy", "KVPressureRebalancer", "make_rebalancer",
    "WorkerView", "FleetView", "RequestView", "RebalanceDecision",
    "NoFeasibleWorker", "StragglerTracker",
    "snapshot", "fleet_snapshot", "eligible_indices",
    "ClusterConfig", "ClusterRuntime",
    "Worker", "make_sim_worker",
]
