"""Cluster serving layer: open-loop arrival replay, disaggregated
prefill/decode pools, pluggable routing, and SLO-goodput accounting."""
from repro.cluster.arrivals import (ArrivalProcess, GammaProcess,
                                    PoissonProcess, TraceEntry, TraceProcess,
                                    assign_classes, load_trace, make_trace,
                                    save_trace)
from repro.cluster.metrics import ClusterMetrics, MigrationRecord
from repro.cluster.policies import (DispatchPolicy, JoinShortestQueue,
                                    LeastKVHeadroom, MemoryAware,
                                    MostKVHeadroom, RoundRobin, RoutingPolicy,
                                    make_dispatcher, make_policy)
from repro.cluster.runtime import ClusterConfig, ClusterRuntime
from repro.cluster.worker import Worker, make_sim_worker

__all__ = [
    "ArrivalProcess", "PoissonProcess", "GammaProcess", "TraceProcess",
    "TraceEntry", "make_trace", "assign_classes", "save_trace", "load_trace",
    "ClusterMetrics", "MigrationRecord",
    "RoutingPolicy", "RoundRobin", "JoinShortestQueue", "MemoryAware",
    "DispatchPolicy", "LeastKVHeadroom", "MostKVHeadroom",
    "make_policy", "make_dispatcher",
    "ClusterConfig", "ClusterRuntime",
    "Worker", "make_sim_worker",
]
