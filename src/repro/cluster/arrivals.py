"""Open-loop arrival processes and workload traces (paper §III-B).

Closed-loop, all-at-once submission (every benchmark before the cluster layer)
hides the serving-level dynamics the paper measures: queueing delay, the
first-saturating replica, and the goodput cliff under rising load. The
cluster runtime instead replays an *open-loop* trace — requests arrive on a
stochastic process regardless of completion — which is what "heavy traffic
from millions of users" looks like to a fleet.

``PoissonProcess``  — memoryless arrivals at `rate` req/s (M/G/k baseline).
``GammaProcess``    — gamma inter-arrivals with a coefficient of variation:
                      cv > 1 models bursty traffic, cv < 1 smoothed traffic.
``TraceProcess``    — explicit arrival times (replay a recorded trace).

``make_trace`` glues a process to the Natural-Reasoning (ISL, OSL) sampler in
``repro.data.reasoning`` producing ``TraceEntry`` rows the runtime replays.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.data.reasoning import WorkloadSpec, sample


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    arrival: float
    isl: int
    osl: int
    slo_class: str = ""           # multi-tenant tier tag ("" = default class)


class ArrivalProcess:
    """Yields n monotone non-decreasing arrival times starting at t0."""

    def times(self, n: int, seed: int = 0, t0: float = 0.0) -> List[float]:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PoissonProcess(ArrivalProcess):
    rate: float                       # mean arrivals per second

    def times(self, n: int, seed: int = 0, t0: float = 0.0) -> List[float]:
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1.0 / self.rate, size=n)
        return (t0 + np.cumsum(gaps)).tolist()


@dataclasses.dataclass(frozen=True)
class GammaProcess(ArrivalProcess):
    """Gamma inter-arrival renewal process: cv=1 is Poisson; cv>1 bursty."""
    rate: float
    cv: float = 2.0                   # coefficient of variation of the gaps

    def times(self, n: int, seed: int = 0, t0: float = 0.0) -> List[float]:
        rng = np.random.default_rng(seed)
        shape = 1.0 / (self.cv ** 2)
        scale = 1.0 / (self.rate * shape)
        gaps = rng.gamma(shape, scale, size=n)
        return (t0 + np.cumsum(gaps)).tolist()


@dataclasses.dataclass(frozen=True)
class TraceProcess(ArrivalProcess):
    arrivals: Sequence[float]

    def times(self, n: int, seed: int = 0, t0: float = 0.0) -> List[float]:
        ts = sorted(self.arrivals)[:n]
        if len(ts) < n:
            raise ValueError(f"trace has {len(ts)} arrivals, need {n}")
        return [t0 + t for t in ts]


def make_trace(process: ArrivalProcess, spec: WorkloadSpec, n: int,
               seed: int = 0, osl_cap: Optional[int] = None
               ) -> List[TraceEntry]:
    """Open-loop workload: arrival process x Natural-Reasoning (ISL, OSL)."""
    ts = process.times(n, seed=seed)
    lens = sample(spec, n, seed=seed + 1)
    cap = osl_cap or 10 ** 9
    return [TraceEntry(arrival=float(t), isl=int(i), osl=int(min(o, cap)))
            for t, (i, o) in zip(ts, lens)]


def assign_classes(trace: List[TraceEntry],
                   mix: Sequence[Tuple[str, float]],
                   seed: int = 0) -> List[TraceEntry]:
    """Deterministically tag each entry with an SLO class drawn from ``mix``
    (name, weight) pairs — the multi-tenant per-class traffic split. The same
    seed always produces the same tagging, so class-aware and class-blind
    fleets compared on one trace see identical per-request tiers."""
    if not mix:
        return list(trace)
    names = [n for n, _ in mix]
    w = np.asarray([x for _, x in mix], dtype=float)
    if (w < 0).any() or w.sum() <= 0:
        raise ValueError(f"class mix weights must be non-negative with a "
                         f"positive sum: {list(mix)}")
    rng = np.random.default_rng(seed)
    picks = rng.choice(len(names), size=len(trace), p=w / w.sum())
    return [dataclasses.replace(e, slo_class=names[k])
            for k, e in zip(picks, trace)]


def save_trace(path: str, trace: List[TraceEntry]):
    with open(path, "w") as f:
        for e in trace:
            f.write(json.dumps(dataclasses.asdict(e)) + "\n")


def load_trace(path: str) -> List[TraceEntry]:
    out = []
    with open(path) as f:
        for line in f:
            if line.strip():
                d = json.loads(line)
                out.append(TraceEntry(float(d["arrival"]), int(d["isl"]),
                                      int(d["osl"]),
                                      str(d.get("slo_class", ""))))
    return out
