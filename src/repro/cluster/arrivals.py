"""Open-loop arrival processes and workload traces (paper §III-B).

Closed-loop, all-at-once submission (every benchmark before the cluster layer)
hides the serving-level dynamics the paper measures: queueing delay, the
first-saturating replica, and the goodput cliff under rising load. The
cluster runtime instead replays an *open-loop* trace — requests arrive on a
stochastic process regardless of completion — which is what "heavy traffic
from millions of users" looks like to a fleet.

``PoissonProcess``   — memoryless arrivals at `rate` req/s (M/G/k baseline).
``GammaProcess``     — gamma inter-arrivals with a coefficient of variation:
                       cv > 1 models bursty traffic, cv < 1 smoothed traffic.
``TraceProcess``     — explicit arrival times (replay a recorded trace).
``PiecewiseRateProcess`` — piecewise-constant-rate Poisson phases
                       (diurnal / ramp / burst): the time-varying load a
                       scaling controller exists to track — constant-rate
                       processes cannot exercise an autoscaler.

``make_trace`` glues a process to the Natural-Reasoning (ISL, OSL) sampler in
``repro.data.reasoning`` producing ``TraceEntry`` rows the runtime replays.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.data.reasoning import WorkloadSpec, sample


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    arrival: float
    isl: int
    osl: int
    slo_class: str = ""           # multi-tenant tier tag ("" = default class)


class ArrivalProcess:
    """Yields n monotone non-decreasing arrival times starting at t0."""

    def times(self, n: int, seed: int = 0, t0: float = 0.0) -> List[float]:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PoissonProcess(ArrivalProcess):
    rate: float                       # mean arrivals per second

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(
                f"PoissonProcess needs rate > 0 req/s, got {self.rate}")

    def times(self, n: int, seed: int = 0, t0: float = 0.0) -> List[float]:
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1.0 / self.rate, size=n)
        return (t0 + np.cumsum(gaps)).tolist()


@dataclasses.dataclass(frozen=True)
class GammaProcess(ArrivalProcess):
    """Gamma inter-arrival renewal process: cv=1 is Poisson; cv>1 bursty."""
    rate: float
    cv: float = 2.0                   # coefficient of variation of the gaps

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(
                f"GammaProcess needs rate > 0 req/s, got {self.rate}")
        if self.cv <= 0:
            raise ValueError(f"GammaProcess needs cv > 0 (the gap "
                             f"coefficient of variation), got {self.cv}")

    def times(self, n: int, seed: int = 0, t0: float = 0.0) -> List[float]:
        rng = np.random.default_rng(seed)
        shape = 1.0 / (self.cv ** 2)
        scale = 1.0 / (self.rate * shape)
        gaps = rng.gamma(shape, scale, size=n)
        return (t0 + np.cumsum(gaps)).tolist()


@dataclasses.dataclass(frozen=True)
class TraceProcess(ArrivalProcess):
    arrivals: Sequence[float]

    def times(self, n: int, seed: int = 0, t0: float = 0.0) -> List[float]:
        ts = sorted(self.arrivals)[:n]
        if len(ts) < n:
            raise ValueError(f"trace has {len(ts)} arrivals, need {n}")
        return [t0 + t for t in ts]


@dataclasses.dataclass(frozen=True)
class PiecewiseRateProcess(ArrivalProcess):
    """Nonhomogeneous Poisson with a piecewise-constant rate: ``phases`` is a
    sequence of (duration_s, rate) segments replayed in order. With
    ``repeat=True`` the schedule cycles (a diurnal day repeats); otherwise the
    final phase's rate extends forever. A zero-rate phase is a silent gap —
    arrivals jump over it. Memorylessness makes per-phase sampling exact:
    within a phase, gaps are Exp(rate); at a boundary the partial gap is
    re-drawn at the new rate (valid by the Markov property)."""
    phases: Tuple[Tuple[float, float], ...]
    repeat: bool = True

    def __post_init__(self):
        phases = tuple((float(d), float(r)) for d, r in self.phases)
        object.__setattr__(self, "phases", phases)
        if not phases:
            raise ValueError("PiecewiseRateProcess needs at least one "
                             "(duration_s, rate) phase")
        if any(d <= 0 for d, _ in phases):
            raise ValueError(f"phase durations must be > 0: {phases}")
        if any(r < 0 for _, r in phases):
            raise ValueError(f"phase rates must be >= 0: {phases}")
        if not any(r > 0 for _, r in phases):
            raise ValueError(f"at least one phase needs rate > 0: {phases}")

    def rate_at(self, t: float) -> float:
        """The instantaneous arrival rate at time t (relative to t0)."""
        period = sum(d for d, _ in self.phases)
        if self.repeat:
            t = t % period
        elif t >= period:
            return self.phases[-1][1]
        for d, r in self.phases:
            if t < d:
                return r
            t -= d
        return self.phases[-1][1]

    def times(self, n: int, seed: int = 0, t0: float = 0.0) -> List[float]:
        rng = np.random.default_rng(seed)
        out: List[float] = []
        t = 0.0                       # clock relative to t0
        k = 0                         # phase index
        phase_end = self.phases[0][0]
        while len(out) < n:
            rate = self.phases[k][1]
            if rate <= 0:
                t = phase_end
            else:
                t += rng.exponential(1.0 / rate)
            if t >= phase_end:
                if k + 1 < len(self.phases):
                    k += 1
                elif self.repeat:
                    k = 0
                else:                 # last phase extends forever
                    if rate > 0:
                        out.append(t0 + t)
                    else:
                        raise ValueError(
                            f"non-repeating schedule ends at rate 0 with "
                            f"only {len(out)}/{n} arrivals drawn")
                    continue
                t = phase_end         # re-draw the partial gap (memoryless)
                phase_end += self.phases[k][0]
                continue
            out.append(t0 + t)
        return out


def make_trace(process: ArrivalProcess, spec: WorkloadSpec, n: int,
               seed: int = 0, osl_cap: Optional[int] = None
               ) -> List[TraceEntry]:
    """Open-loop workload: arrival process x Natural-Reasoning (ISL, OSL)."""
    ts = process.times(n, seed=seed)
    lens = sample(spec, n, seed=seed + 1)
    cap = osl_cap or 10 ** 9
    return [TraceEntry(arrival=float(t), isl=int(i), osl=int(min(o, cap)))
            for t, (i, o) in zip(ts, lens)]


def assign_classes(trace: List[TraceEntry],
                   mix: Sequence[Tuple[str, float]],
                   seed: int = 0) -> List[TraceEntry]:
    """Deterministically tag each entry with an SLO class drawn from ``mix``
    (name, weight) pairs — the multi-tenant per-class traffic split. The same
    seed always produces the same tagging, so class-aware and class-blind
    fleets compared on one trace see identical per-request tiers."""
    if not mix:
        return list(trace)
    names = [n for n, _ in mix]
    w = np.asarray([x for _, x in mix], dtype=float)
    if (w < 0).any() or w.sum() <= 0:
        raise ValueError(f"class mix weights must be non-negative with a "
                         f"positive sum: {list(mix)}")
    rng = np.random.default_rng(seed)
    picks = rng.choice(len(names), size=len(trace), p=w / w.sum())
    return [dataclasses.replace(e, slo_class=names[k])
            for k, e in zip(picks, trace)]


def save_trace(path: str, trace: List[TraceEntry]):
    with open(path, "w") as f:
        for e in trace:
            f.write(json.dumps(dataclasses.asdict(e)) + "\n")


def load_trace(path: str) -> List[TraceEntry]:
    out = []
    with open(path) as f:
        for line in f:
            if line.strip():
                d = json.loads(line)
                out.append(TraceEntry(float(d["arrival"]), int(d["isl"]),
                                      int(d["osl"]),
                                      str(d.get("slo_class", ""))))
    return out
