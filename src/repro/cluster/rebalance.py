"""Decode→decode rebalancing: shed load off a saturating decode worker
*before* the preemption storm (paper Obs 4 mitigation).

The paper's Obs 4: "tail latency is dominated by the replica that reaches KV
saturation first" — once a decode worker's page pool fills, every further
token grows someone's context across a page boundary and the scheduler
starts evicting (recompute preemption), burning the very compute the fleet
is short of. Rebalancing is the whole-fleet answer: when one worker crosses
a KV-pressure threshold WHILE a peer still has headroom — a condition only
expressible on a fleet-wide view — migrate one victim to the peer over the
existing eject / ``kv_transfer_time`` / inject path, trading one bounded
transfer for the unbounded recompute a storm would cost.

``RebalancePolicy`` is a pure decision function on the frozen
:class:`~repro.cluster.view.FleetView` (lint rule REP010 keeps engine
internals out); actuation — eject, transfer accounting, pinned-destination
delivery — lives in ``ClusterRuntime``, which ticks the policy in its event
loop and emits a ``rebalance`` event per decision. Victim choice uses the
scheduler's own :func:`~repro.core.scheduler.victim_order` (least urgent,
most recently arrived), so migrating away and preempting agree about who is
cheapest to disturb.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.scheduler import victim_order
from repro.cluster.view import FleetView, RebalanceDecision, WorkerView


class RebalancePolicy:
    """(fleet view) -> at most one migration decision per tick.

    Pure decision logic: the runtime actuates (ejects the victim, pays the
    modeled KV transfer, delivers to the pinned destination) and enforces
    nothing — a policy returning ``None`` forever leaves the event loop
    bit-identical to a fleet with rebalancing disabled."""

    def decide(self, fleet: FleetView) -> Optional[RebalanceDecision]:
        raise NotImplementedError


@dataclasses.dataclass
class KVPressureRebalancer(RebalancePolicy):
    """Migrate one victim off the most KV-pressured decode worker to the
    peer with the most post-adoption headroom.

    Triggers when a worker's KV utilization crosses ``kv_high`` (default
    0.90 — the same saturation threshold the ``repro.obs`` regime classifier
    uses for Capacity-Bound, ``RegimeRules.kv_saturated``) while some peer
    could adopt the victim and keep ``dst_headroom`` of its pool free.
    ``cooldown_s`` rate-limits decisions and ``max_inflight`` keeps at most
    that many rebalance transfers in flight — one bad tick must not empty a
    worker through parallel migrations it decided on one stale view."""
    kv_high: float = 0.90
    dst_headroom: float = 0.10
    min_remaining: int = 64       # don't ship a nearly-finished decode: the
                                  # transfer costs more than it frees
    cooldown_s: float = 0.25
    max_inflight: int = 1
    _last_t: float = dataclasses.field(default=float("-inf"), init=False,
                                       repr=False)

    def decide(self, fleet: FleetView) -> Optional[RebalanceDecision]:
        if fleet.inflight_rebalances >= self.max_inflight:
            return None
        if fleet.t - self._last_t < self.cooldown_s:
            return None
        pool = fleet.pool("decode") or fleet.pool("colocated")
        if len(pool) < 2:
            return None
        pressured = [v for v in pool
                     if v.kv_util >= self.kv_high and v.n_running >= 2]
        if not pressured:
            return None
        src = max(pressured, key=lambda v: (v.kv_util, v.name))
        victim = self._pick_victim(src)
        if victim is None:
            return None
        dst = self._pick_destination(pool, src, victim)
        if dst is None:
            return None
        self._last_t = fleet.t
        return RebalanceDecision(
            rid=victim.rid, src=src.name, dst=dst.name,
            kv_util=src.kv_util,
            reason=f"kv_util {src.kv_util:.3f} >= {self.kv_high} "
                   f"with peer headroom on {dst.name}")

    # ------------------------------------------------------------- internals
    def _pick_victim(self, src: WorkerView):
        """The same total order engine preemption uses (least urgent class,
        most recent arrival): the request preemption would evict anyway is
        the one worth shipping out before it is. Only decode-phase requests
        qualify — a mid-prefill request has no KV worth moving, and inject
        adopts running (prefill-complete) requests only."""
        cands = [r for r in src.running_reqs
                 if r.prefill_done and r.generated >= 1
                 and r.remaining >= self.min_remaining]
        if not cands:
            return None
        return max(cands, key=lambda r: victim_order(r.urgency, r.arrival,
                                                     r.rid))

    def _pick_destination(self, pool, src: WorkerView, victim):
        """Peer with the most predicted headroom AFTER adopting the victim,
        required to keep ``dst_headroom`` of its pool free and a batch slot
        open — a destination this migration would itself push to the wall is
        no mitigation, it just moves the storm."""
        best = None
        for v in pool:
            if v.name == src.name or v.draining \
                    or v.n_running >= v.max_seqs:
                continue
            need = v.pages_for(victim.context_len + victim.remaining + 1)
            head = v.predicted_headroom_pages() - need
            if head < self.dst_headroom * v.n_pages:
                continue
            if best is None or (head, v.name) > best[0]:
                best = ((head, v.name), v)
        return best[1] if best is not None else None


REBALANCERS = {"kv_pressure": KVPressureRebalancer}


def make_rebalancer(name: str, **kw) -> RebalancePolicy:
    if name not in REBALANCERS:
        raise ValueError(f"unknown rebalance policy {name!r} "
                         f"(have {sorted(REBALANCERS)})")
    return REBALANCERS[name](**kw)
