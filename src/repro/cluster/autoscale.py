"""Elastic autoscaling: grow/shrink worker pools under time-varying load.

The paper's Obs 3/4 put reasoning fleets in a Capacity-Bound regime — the
first replica to saturate its KV pool sets the fleet tail — so a *statically
sized* fleet must be provisioned for the peak and strands compute off-peak
(the utilization gap fixed-degree deployments pay). Long-CoT workloads make
load swings large and *slow*, which is exactly the regime where a controller
with hysteresis beats static sizing: swings persist for many controller
periods, so tracking them wins worker-seconds without flapping.

Three pieces:

``ScalingSignals``       — windowed EWMAs of the fleet state a controller
                           acts on: KV saturation, queue backlog, SLO
                           attainment, estimated arrival rate.
``AutoscalePolicy``      — pure decision functions (signals, pool size) ->
                           desired replica delta. ``TargetUtilization``
                           tracks a KV-utilization set-point inside a
                           hysteresis band; ``SLOGuard`` scales up whenever
                           SLO attainment dips (or saturation threatens) and
                           down only when attainment is safe AND the pool is
                           demonstrably oversized.
``AutoscaleController``  — ticks on the cluster's virtual clock between
                           fleet events, observes signals, applies per-role
                           min/max bounds and a cooldown, and mints/retires
                           replicas through ``ClusterRuntime.add_worker`` /
                           ``retire_worker``. New replicas pay the modeled
                           cold start (``pm.weight_load_time`` — the
                           HBM-ingest lower bound — plus an optional
                           ``cold_start_extra_s`` for checkpoint fetch /
                           container spin-up) before joining the pool.

Observation is read-only: a tick that takes no action leaves the simulation
bit-identical to the static path (the acceptance bar for pool-mutation
support).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Sequence

from repro.core.metrics import SLO
from repro.cluster.view import FleetView, WorkerView
from repro.cluster.worker import Worker
from repro.obs.regimes import RegimeRules


# ------------------------------------------------------------------- signals
@dataclasses.dataclass
class ScalingSignals:
    """EWMA-smoothed fleet signals, updated once per controller tick.

    Raw per-tick observations are noisy (a tick may see zero finishes, or a
    transient queue spike); the EWMA gives the controller a windowed view
    whose memory is ``~1/ewma_alpha`` ticks — the hysteresis that keeps one
    burst from flapping the pool. ``None`` means "never observed" (attainment
    additionally holds its last value across ticks with no finishes)."""
    ewma_alpha: float = 0.4
    kv_util: Optional[float] = None         # mean pool KV-page utilization
    queue_depth: Optional[float] = None     # mean waiting requests / worker
    slo_attainment: Optional[float] = None  # attainment of recent finishes
    arrival_rate: Optional[float] = None    # est. arrivals/s into the fleet
    # fraction of the pool that is Capacity-Bound by the repro.obs regime
    # rules (preemption evidence this tick, or KV at/above
    # ``RegimeRules.kv_saturated`` while requests queue). Preemptions are
    # *events*, not levels: one worker's storm barely moves the pool-mean
    # kv_util EWMA, but flips this fraction — the classifier's evidence,
    # available to the controller a tick earlier than the KV mean crosses
    # any ceiling
    capacity_frac: Optional[float] = None
    # slow-EWMA rate baseline (alpha/8): the load the pool has demonstrably
    # been absorbing. fast/slow >> 1 is a surge — the LEADING scale-up
    # indicator (KV fill and queue growth lag a rate step by seconds, and
    # attainment only reports a blown TTFT when the request finishes)
    arrival_rate_slow: Optional[float] = None
    warmup_ticks: int = 8         # observations before the slow baseline
                                  # (and thus the surge ratio) is trusted
    n_obs: int = 0

    def __post_init__(self):
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha must be in (0, 1], got "
                             f"{self.ewma_alpha}")

    def _blend(self, prev: Optional[float], raw: Optional[float],
               alpha: Optional[float] = None) -> Optional[float]:
        if raw is None:
            return prev                     # no observation: hold
        if prev is None:
            return raw                      # first observation seeds
        a = self.ewma_alpha if alpha is None else alpha
        return (1.0 - a) * prev + a * raw

    def observe(self, *, kv_util: Optional[float] = None,
                queue_depth: Optional[float] = None,
                attainment: Optional[float] = None,
                arrival_rate: Optional[float] = None,
                capacity_frac: Optional[float] = None):
        self.kv_util = self._blend(self.kv_util, kv_util)
        self.queue_depth = self._blend(self.queue_depth, queue_depth)
        self.slo_attainment = self._blend(self.slo_attainment, attainment)
        self.arrival_rate = self._blend(self.arrival_rate, arrival_rate)
        self.capacity_frac = self._blend(self.capacity_frac, capacity_frac)
        if arrival_rate is not None and self.n_obs < self.warmup_ticks:
            # arithmetic mean while warming up: an EWMA would anchor on the
            # first (noisy) observation for ~1/alpha_slow ticks, and a biased
            # baseline reads as a phantom surge
            prev = self.arrival_rate_slow or 0.0
            self.arrival_rate_slow = \
                prev + (arrival_rate - prev) / (self.n_obs + 1)
        else:
            self.arrival_rate_slow = self._blend(
                self.arrival_rate_slow, arrival_rate, self.ewma_alpha / 8.0)
        self.n_obs += 1

    def surge_ratio(self) -> float:
        """Fast-to-slow arrival-rate ratio: ~1 in steady state, >>1 within a
        tick or two of a load step. 1.0 when either estimate is missing or
        the slow baseline hasn't warmed up (a freshly seeded baseline tracks
        the fast EWMA too closely to mean anything)."""
        if self.n_obs < self.warmup_ticks:
            return 1.0
        if not self.arrival_rate or not self.arrival_rate_slow:
            return 1.0
        return self.arrival_rate / max(self.arrival_rate_slow, 1e-9)


# ------------------------------------------------------------------ policies
class AutoscalePolicy:
    """(signals, provisioned pool size) -> desired replica delta.

    Pure decision logic: bounds, cooldown and actuation live in the
    controller. ``n_provisioned`` counts warming replicas — capacity already
    bought must damp further scale-ups (no thundering herd while the first
    replica is still loading weights)."""

    def desired_delta(self, s: ScalingSignals, n_provisioned: int) -> int:
        raise NotImplementedError


@dataclasses.dataclass
class TargetUtilization(AutoscalePolicy):
    """Track a KV-utilization set-point inside a hysteresis band.

    Above ``target + band``: add a replica (two when saturation is imminent —
    past ``target + 2*band`` the KV wall is close and one cold start of lag
    costs a preemption storm, Obs 4). Below ``target - band`` with no queue
    backlog: remove one. Inside the band: hold — the dead zone is what keeps
    a noisy signal from flapping the pool."""
    target: float = 0.60
    band: float = 0.15
    up_queue_depth: float = 4.0       # backlog/worker that forces a scale-up
                                      # even below the band (admission-blocked
                                      # fleets pin kv_util while queues grow)
    down_queue_depth: float = 0.5     # max backlog/worker to allow scale-down

    def desired_delta(self, s: ScalingSignals, n_provisioned: int) -> int:
        u, q = s.kv_util, s.queue_depth
        if u is None:
            return 0
        if q is not None and q > self.up_queue_depth:
            return 2
        if u > self.target + self.band:
            return 2 if u > min(self.target + 2 * self.band, 0.95) else 1
        if u < self.target - self.band \
                and (q is None or q <= self.down_queue_depth):
            return -1
        return 0


@dataclasses.dataclass
class SLOGuard(AutoscalePolicy):
    """Scale up whenever the SLO is in danger; scale down only when it is
    demonstrably safe AND the pool is oversized.

    Danger = attainment EWMA below ``attain_floor``, KV utilization above
    ``util_ceiling`` (the saturation precursor — Obs 4's preemption storm
    follows it), queue backlog past ``up_queue_depth``, or an arrival-rate
    *surge* (fast/slow rate EWMAs diverging past ``surge_ratio``). The surge
    term is feedforward: every other signal lags a load step by seconds (KV
    fills at prefill speed, attainment only reports a blown TTFT when the
    request finishes), but the rate jump is visible within a tick — and a
    pool that was attaining at the slow rate needs capacity scaled by the
    rate ratio to keep attaining (utilization-preserving resize), so the
    surge delta is proportional, not incremental. Safe = attainment at/above
    the floor plus margin, utilization below ``scale_down_util``, and
    near-empty queues. The asymmetry is deliberate: an SLO miss costs
    goodput immediately, an extra replica costs worker-seconds slowly."""
    attain_floor: float = 0.90
    margin: float = 0.03
    util_ceiling: float = 0.85
    scale_down_util: float = 0.35
    up_queue_depth: float = 4.0
    down_queue_depth: float = 0.5
    surge_ratio: float = 1.5
    surge_hold: int = 2           # consecutive surging ticks before acting
                                  # (one Poisson spike is noise, two are load)
    # opt-in Capacity-Bound trigger: scale up when the EWMA fraction of the
    # pool classified Capacity-Bound (``ScalingSignals.capacity_frac`` — the
    # repro.obs regime evidence: preemptions, or saturated KV while queued)
    # exceeds this. Fires a tick earlier than the pool-mean KV EWMA on a
    # surge: one replica's preemption storm flips its regime bit immediately
    # while the fleet KV mean is still averaging it away. ``None`` disables
    # (bit-identical to the pre-regime controller).
    capacity_frac_ceiling: Optional[float] = None
    _surge_run: int = dataclasses.field(default=0, init=False, repr=False)

    def desired_delta(self, s: ScalingSignals, n_provisioned: int) -> int:
        att, u, q = s.slo_attainment, s.kv_util, s.queue_depth
        ratio = s.surge_ratio()
        attaining = att is None or att >= self.attain_floor
        if ratio > self.surge_ratio and attaining:
            self._surge_run += 1
            if self._surge_run >= self.surge_hold:
                # the slow rate is a valid capacity reference only while
                # the pool still attains at it
                return max(1, math.ceil(n_provisioned * (ratio - 1.0)))
        else:
            self._surge_run = 0
        hurt = att is not None and att < self.attain_floor
        saturating = u is not None and u > self.util_ceiling
        backlogged = q is not None and q > self.up_queue_depth
        pressured = self.capacity_frac_ceiling is not None \
            and s.capacity_frac is not None \
            and s.capacity_frac > self.capacity_frac_ceiling
        if hurt or saturating or backlogged or pressured:
            # attainment already collapsing = the controller is late:
            # take two steps, cold starts are serial lag otherwise
            return 2 if (hurt and saturating) or backlogged else 1
        safe = att is None or att >= min(self.attain_floor + self.margin, 1.0)
        idle = u is not None and u < self.scale_down_util
        drained = q is None or q <= self.down_queue_depth
        if safe and idle and drained:
            return -1
        return 0


POLICIES = {"target_utilization": TargetUtilization, "slo_guard": SLOGuard}


def make_autoscale_policy(name: str, **kw) -> AutoscalePolicy:
    if name not in POLICIES:
        raise ValueError(f"unknown autoscale policy {name!r} "
                         f"(have {sorted(POLICIES)})")
    return POLICIES[name](**kw)


# ---------------------------------------------------------------- controller
class AutoscaleController:
    """Ticks on the cluster's virtual clock; observes, decides, actuates.

    ``worker_factory`` mints a fresh (virtual-clock) ``Worker`` for the
    scaled role — the Scenario compiler wires one up from the role's
    ``WorkerGroup``, so minted replicas match the group's capacity and
    admission settings exactly. Bounds are per-role: the provisioned count
    (active + warming) always stays in [min_workers, max_workers].
    ``cooldown_s`` rate-limits actions; the policies' hysteresis bands
    prevent flapping between them."""

    def __init__(self, policy: AutoscalePolicy,
                 worker_factory: Callable[[], Worker],
                 role: str = "colocated", min_workers: int = 1,
                 max_workers: int = 8, tick_s: float = 2.0,
                 cooldown_s: float = 10.0, slo: Optional[SLO] = None,
                 ewma_alpha: float = 0.4, cold_start_extra_s: float = 0.0):
        if min_workers < 1 or max_workers < min_workers:
            raise ValueError(f"need 1 <= min_workers <= max_workers, got "
                             f"[{min_workers}, {max_workers}]")
        if tick_s <= 0:
            raise ValueError(f"tick_s must be > 0, got {tick_s}")
        if cooldown_s < 0:
            raise ValueError(f"cooldown_s must be >= 0, got {cooldown_s}")
        self.policy = policy
        self.worker_factory = worker_factory
        self.role = role
        self.min_workers = min_workers
        self.max_workers = max_workers
        self.tick_s = tick_s
        self.cooldown_s = cooldown_s
        self.slo = slo
        self.cold_start_extra_s = cold_start_extra_s
        self.signals = ScalingSignals(ewma_alpha=ewma_alpha)
        self.regime_rules = RegimeRules()
        self.next_tick: Optional[float] = tick_s
        self._last_tick_t = 0.0
        self._last_action_t: Optional[float] = None
        self._last_preempt: Dict[str, int] = {}
        self.n_scale_ups = 0
        self.n_scale_downs = 0

    # ----------------------------------------------------------- observation
    def _capacity_bound(self, v: WorkerView) -> bool:
        """The repro.obs Capacity-Bound evidence, on view fields: the worker
        preempted since the last tick (storm), or its KV pool sits at/above
        the saturation threshold while requests queue behind it
        (KV-throttled admission)."""
        preempted = v.preemptions - self._last_preempt.get(v.name, 0) > 0
        throttled = v.kv_util >= self.regime_rules.kv_saturated \
            and v.n_waiting > 0
        return preempted or throttled

    def _observe(self, fleet: FleetView, t: float,
                 pool: Sequence[WorkerView]):
        dt = max(t - self._last_tick_t, 1e-9)
        kv = sum(v.kv_util for v in pool) / len(pool) if pool else None
        queue = sum(v.n_waiting for v in pool) / len(pool) if pool else None
        cap = sum(1 for v in pool
                  if self._capacity_bound(v)) / len(pool) if pool else None
        for v in pool:
            self._last_preempt[v.name] = v.preemptions
        # arrivals in (last_tick, t]: the view's arrival series covers routed
        # requests AND the not-yet-routed remainder in the runtime's heap —
        # disjoint sets, so each arrival is counted in exactly one window
        arrived = sum(1 for ta in fleet.arrivals
                      if self._last_tick_t < ta <= t)
        att = None
        if self.slo is not None:
            fin = [r for r in fleet.finished
                   if r.t_finished is not None
                   and self._last_tick_t < r.t_finished <= t]
            if fin:
                att = sum(self.slo.attained(r) for r in fin) / len(fin)
        self.signals.observe(kv_util=kv, queue_depth=queue, attainment=att,
                             arrival_rate=arrived / dt, capacity_frac=cap)

    # -------------------------------------------------------------- actuation
    def tick(self, rt, t: float):
        """One controller period: observe -> decide -> clamp -> actuate.
        Called by the runtime's event loop with the fleet quiescent at
        virtual time ``t``; always schedules the next tick. Observation is
        one frozen ``FleetView`` — the same decision plane routing, dispatch
        and rebalancing read."""
        fleet = rt.fleet_view(t)
        pool = fleet.pool(self.role)
        warming = fleet.warming_count(self.role)
        self._observe(fleet, t, pool)
        n = len(pool) + warming
        delta = self.policy.desired_delta(self.signals, n)
        if warming and delta < 0:
            delta = 0          # capacity already in flight: let it land first
        delta = max(self.min_workers - n, min(self.max_workers - n, delta))
        in_cooldown = self._last_action_t is not None \
            and t - self._last_action_t < self.cooldown_s
        if delta != 0:
            # the decision itself goes on the event spine (whether or not
            # cooldown suppresses actuation); the resulting mint/retire
            # lifecycle events are emitted by the runtime's mutators
            rt.emitter.emit("scale_decision", t=t, delta=delta,
                            actuated=not in_cooldown, n_active=len(pool),
                            n_warming=warming, role=self.role)
        if delta != 0 and not in_cooldown:
            if delta > 0:
                for _ in range(delta):
                    rt.add_worker(self.worker_factory(), at=t,
                                  cold_start_extra_s=self.cold_start_extra_s)
                self.n_scale_ups += delta
            else:
                for _ in range(-delta):
                    rt.retire_worker(role=self.role, at=t)
                self.n_scale_downs += -delta
            self._last_action_t = t
        self._last_tick_t = t
        self.next_tick = t + self.tick_s


def make_autoscaler(spec, worker_factory: Callable[[], Worker],
                    slo: Optional[SLO] = None) -> AutoscaleController:
    """Build a controller from a ``repro.scenario.spec.Autoscaler`` (duck-
    typed: anything carrying the spec's fields works). ``slo`` is the target
    the ``slo_guard`` policy's attainment signal is judged against —
    typically the scenario's default SLO class."""
    if spec.policy == "target_utilization":
        policy: AutoscalePolicy = TargetUtilization(
            target=spec.target_kv_util, band=spec.band)
    elif spec.policy == "slo_guard":
        policy = SLOGuard(attain_floor=spec.attain_floor,
                          util_ceiling=spec.util_ceiling,
                          scale_down_util=spec.scale_down_util,
                          surge_ratio=spec.surge_ratio,
                          capacity_frac_ceiling=getattr(
                              spec, "capacity_frac_ceiling", None))
    else:
        raise ValueError(f"unknown autoscale policy {spec.policy!r} "
                         f"(have {sorted(POLICIES)})")
    return AutoscaleController(
        policy=policy, worker_factory=worker_factory, role=spec.role,
        min_workers=spec.min_workers, max_workers=spec.max_workers,
        tick_s=spec.tick_s, cooldown_s=spec.cooldown_s, slo=slo,
        ewma_alpha=spec.ewma_alpha,
        cold_start_extra_s=spec.cold_start_extra_s)
