"""Event-driven cluster runtime: many engines, one virtual clock.

Drives a heterogeneous fleet of `InferenceEngine` instances (possibly with
different `ParallelismPlan`s / `Hardware`) as a conservative discrete-event
simulation: each iteration advances the worker whose next action is earliest,
so worker clocks stay causally consistent and fleet-level timestamps
(arrival -> route -> admit -> first token -> migrate -> finish) are monotone
along every request's path.

Two serving modes:

  colocated     — every worker runs prefill+decode interleaved; new requests
                  are routed by a pluggable `RoutingPolicy` (the paper's DP
                  baseline, §V-B).
  disaggregated — prefill workers run chunked prefill only; on first token
                  the request is ejected, pays the modeled KV-transfer time
                  (`perf_model.kv_transfer_time` over the inter-node fabric),
                  and is adopted by a decode worker chosen by a
                  `DispatchPolicy` (§III phase divergence made structural).

Open-loop arrivals: the runtime holds the trace and routes each request when
the cluster clock reaches its arrival; engines additionally gate admission on
`arrival > now` (no scheduler sees a request from the future).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import List, Optional, Sequence, Union

from repro.core import perf_model as pm
from repro.core.request import Request
from repro.cluster.arrivals import TraceEntry
from repro.cluster.metrics import ClusterMetrics, MigrationRecord
from repro.cluster.policies import (DispatchPolicy, RoutingPolicy,
                                    make_dispatcher, make_policy)
from repro.cluster.worker import Worker


@dataclasses.dataclass
class ClusterConfig:
    policy: Union[str, RoutingPolicy] = "memory_aware"
    dispatcher: Union[str, DispatchPolicy] = "least_headroom"
    transfer_dtype_bytes: int = 2     # KV wire format (fp8 transfer: 1)
    snapshot_every: int = 1


class ClusterRuntime:
    def __init__(self, workers: Sequence[Worker],
                 cfg: Optional[ClusterConfig] = None):
        if not workers:
            raise ValueError("cluster needs at least one worker")
        if not all(w.engine.virtual_clock for w in workers):
            raise ValueError("cluster co-simulation requires virtual-clock "
                             "engines (SimRunner)")
        self.workers = list(workers)
        names = [w.name for w in self.workers]
        if len(set(names)) != len(names):
            raise ValueError(f"worker names must be unique (metrics and "
                             f"migration records key on them): {names}")
        self.cfg = cfg or ClusterConfig()
        self.policy = self.cfg.policy if isinstance(self.cfg.policy,
                                                    RoutingPolicy) \
            else make_policy(self.cfg.policy)
        self.dispatcher = self.cfg.dispatcher \
            if isinstance(self.cfg.dispatcher, DispatchPolicy) \
            else make_dispatcher(self.cfg.dispatcher)

        self.prefill_pool = [w for w in self.workers if w.role == "prefill"]
        self.decode_pool = [w for w in self.workers if w.role == "decode"]
        self.colocated_pool = [w for w in self.workers
                               if w.role == "colocated"]
        self.disaggregated = bool(self.prefill_pool)
        if self.disaggregated and not self.decode_pool:
            raise ValueError("prefill workers need a decode pool to "
                             "migrate into")
        # new requests land on prefill workers (disaggregated) or on the
        # colocated fleet
        self.route_pool = self.prefill_pool if self.disaggregated \
            else self.colocated_pool
        if not self.route_pool:
            raise ValueError("no routable workers (prefill or colocated)")

        # request ids key allocator tables; migration moves requests between
        # engines, so the whole fleet shares one counter — seeded past any
        # rid an engine already issued before joining the cluster
        start = 1 + max((r for w in self.workers
                         for r in w.engine.issued_rids()), default=-1)
        rid_source = itertools.count(start)
        for w in self.workers:
            w.engine.adopt_rid_source(rid_source)

        self._arrivals: List = []          # (t, seq, TraceEntry) min-heap
        self._arr_seq = itertools.count()
        self._migrating: List[dict] = []   # in-flight KV transfers
        self.metrics = ClusterMetrics(self.workers)
        self.submitted: List[Request] = []

    # ------------------------------------------------------------------- api
    def submit(self, isl: int, osl: int, arrival: float = 0.0):
        from repro.cluster.policies import pool_capacity_tokens
        if self.disaggregated:
            cap = max(pool_capacity_tokens(w) for w in self.decode_pool)
            if isl + osl + 1 > cap:
                raise ValueError(f"request ({isl} in, {osl} out) exceeds "
                                 f"largest decode-pool KV capacity {cap}")
            pcap = max(pool_capacity_tokens(w) for w in self.prefill_pool)
            if isl + 2 > pcap:
                raise ValueError(f"request prompt ({isl} tokens) exceeds "
                                 f"largest prefill-pool KV capacity {pcap}")
        else:
            cap = max(pool_capacity_tokens(w) for w in self.route_pool)
            if isl + osl + 1 > cap:
                raise ValueError(f"request ({isl} in, {osl} out) exceeds "
                                 f"largest worker KV capacity {cap}")
        heapq.heappush(self._arrivals,
                       (arrival, next(self._arr_seq),
                        TraceEntry(arrival, isl, osl)))

    def submit_trace(self, trace: Sequence[TraceEntry]):
        for e in trace:
            self.submit(e.isl, e.osl, e.arrival)

    def run(self, max_steps: int = 10 ** 7) -> ClusterMetrics:
        for _ in range(max_steps):
            self._deliver_migrations()
            self._route_arrivals()
            w = self._next_worker()
            if w is None:
                if self._migrating:
                    # decode pool saturated and idle: let the retry clock of
                    # the earliest transfer pull the fleet forward
                    t = min(m["ready"] for m in self._migrating)
                    for dw in self.decode_pool:
                        if not dw.engine.sched.has_work:
                            dw.engine.advance_to(t)
                    self._deliver_migrations()
                    if self._next_worker() is None and not self._arrivals:
                        if self._migrating:      # truly wedged: no KV room
                            raise RuntimeError(
                                f"{len(self._migrating)} migrated requests "
                                "cannot fit any decode worker")
                    continue
                if self._arrivals:
                    continue                     # routing will gate-release
                break                            # fleet drained
            t0 = w.engine.now
            w.engine.step()
            if w in self.route_pool:
                self.policy.note_step(self.route_pool.index(w),
                                      w.engine.now - t0)
            if w.role == "prefill":
                self._harvest_prefill_complete(w)
        return self.metrics

    # ------------------------------------------------------------- internals
    def _next_action_time(self, w: Worker) -> Optional[float]:
        if w.engine.sched.has_work:
            return w.engine.now
        nxt = w.engine.next_arrival()
        if nxt is not None:
            return max(w.engine.now, nxt)
        return None

    def _next_worker(self) -> Optional[Worker]:
        best, best_t = None, None
        for w in self.workers:
            t = self._next_action_time(w)
            if t is not None and (best_t is None or t < best_t):
                best, best_t = w, t
        return best

    def _horizon(self) -> Optional[float]:
        """Earliest time anything already in the system acts next."""
        ts = [t for t in (self._next_action_time(w) for w in self.workers)
              if t is not None]
        ts += [m["ready"] for m in self._migrating]
        return min(ts, default=None)

    def _route_arrivals(self):
        while self._arrivals:
            t = self._arrivals[0][0]
            horizon = self._horizon()
            if horizon is not None and t > horizon:
                break                  # the future: in-flight work acts first
            _, _, entry = heapq.heappop(self._arrivals)
            i = self.policy.pick(self.route_pool, entry.isl, entry.osl)
            req = self.route_pool[i].engine.submit(
                entry.isl, entry.osl, arrival=entry.arrival)
            self.submitted.append(req)

    def _harvest_prefill_complete(self, w: Worker):
        done = [r for r in w.engine.sched.running
                if r.prefill_done and r.generated >= 1]
        for req in done:
            w.engine.eject(req)
            hw = w.engine.runner.hw
            tt = pm.kv_transfer_time(w.engine.cfg_model, req.context_len, hw,
                                     self.cfg.transfer_dtype_bytes)
            self._migrating.append({
                "req": req, "src": w.name,
                "eject": w.engine.now, "ready": w.engine.now + tt,
            })

    def _deliver_migrations(self):
        still = []
        for m in sorted(self._migrating, key=lambda m: m["ready"]):
            req, ready = m["req"], m["ready"]
            # delivering to an idle worker fast-forwards its clock to the
            # transfer completion — only allowed when that completion is the
            # fleet's next event, or an earlier-ready transfer (ejected on a
            # later step) would find the idle time already burned
            hz = min((t for t in (self._next_action_time(w)
                                  for w in self.workers) if t is not None),
                     default=float("inf"))
            remaining = req.max_new_tokens - req.generated

            def can_hold(dw):
                return req.context_len + remaining + 1 \
                    <= dw.engine.alloc.n_pages * dw.engine.alloc.page_size

            eligible = [dw for dw in self.decode_pool if can_hold(dw)
                        and (dw.engine.now >= ready
                             or (ready <= hz
                                 and not dw.engine.sched.has_work))]
            i = self.dispatcher.pick(eligible, req) if eligible else None
            if i is None:
                still.append(m)
                continue
            target = eligible[i]
            target.engine.advance_to(ready)
            if not target.engine.inject(req):
                still.append(m)        # no KV/seq room yet: retry next tick
                continue
            self.metrics.note_migration(MigrationRecord(
                rid=req.rid, src=m["src"], dst=target.name,
                t_eject=m["eject"], t_ready=ready,
                t_delivered=target.engine.now,
                context_tokens=req.context_len))
        self._migrating = still
