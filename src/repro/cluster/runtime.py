"""Event-driven cluster runtime: many engines, one virtual clock.

Drives a heterogeneous fleet of `InferenceEngine` instances (possibly with
different `ParallelismPlan`s / `Hardware`) as a conservative discrete-event
simulation: each iteration advances the worker whose next action is earliest,
so worker clocks stay causally consistent and fleet-level timestamps
(arrival -> route -> admit -> first token -> migrate -> finish) are monotone
along every request's path.

Two serving modes:

  colocated     — every worker runs prefill+decode interleaved; new requests
                  are routed by a pluggable `RoutingPolicy` (the paper's DP
                  baseline, §V-B).
  disaggregated — prefill workers run chunked prefill only; on first token
                  the request is ejected, pays the modeled KV-transfer time
                  (`perf_model.kv_transfer_time` over the inter-node fabric),
                  and is adopted by a decode worker chosen by a
                  `DispatchPolicy` (§III phase divergence made structural).

Open-loop arrivals: the runtime holds the trace and routes each request when
the cluster clock reaches its arrival; engines additionally gate admission on
`arrival > now` (no scheduler sees a request from the future).

Elasticity: the pools are mutable mid-run. ``add_worker`` mints a replica
that joins its pool only after a modeled cold start (weight-shard load into
HBM, ``pm.weight_load_time``); ``retire_worker`` removes a replica from the
route/dispatch pools immediately but lets its in-flight requests finish
(graceful drain), stamping a decommission time so worker-second accounting
stays honest. An attached ``AutoscaleController`` ticks on the virtual clock
between fleet events; with no controller and no add/retire calls the event
loop is bit-identical to the static path.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Union

from repro.core import perf_model as pm
from repro.core.admission import ClassPolicy
from repro.core.request import Request
from repro.cluster.arrivals import TraceEntry
from repro.cluster.metrics import ClusterMetrics
from repro.cluster.policies import (DispatchPolicy, RoutingPolicy,
                                    make_dispatcher, make_policy)
from repro.cluster.rebalance import RebalancePolicy, make_rebalancer
from repro.cluster.view import (FleetView, NoFeasibleWorker, StragglerTracker,
                                fleet_snapshot, snapshot)
from repro.cluster.worker import Worker
from repro.trace.events import EventEmitter, EventLog


@dataclasses.dataclass
class ClusterConfig:
    policy: Union[str, RoutingPolicy] = "memory_aware"
    dispatcher: Union[str, DispatchPolicy] = "least_headroom"
    transfer_dtype_bytes: int = 2     # KV wire format (fp8 transfer: 1)
    snapshot_every: int = 1
    # multi-tenant SLO classes: name -> urgency, consulted by routing and
    # dispatch (per-worker scheduling urgency lives in each EngineConfig)
    class_priorities: Dict[str, int] = dataclasses.field(default_factory=dict)
    name: str = ""                    # scenario name, surfaced in errors
    straggler_alpha: float = 0.2      # EWMA half-life of the straggler tracker
    # decode→decode rebalancing: a RebalancePolicy instance, a registry name
    # ("kv_pressure"), or None (disabled — the event loop is then
    # bit-identical to a fleet without the hook)
    rebalance: Union[None, str, RebalancePolicy] = None
    rebalance_every_s: float = 0.05   # how often the event loop consults it


class ClusterRuntime:
    def __init__(self, workers: Sequence[Worker],
                 cfg: Optional[ClusterConfig] = None,
                 autoscaler=None, sanitize: bool = False):
        if not workers:
            raise ValueError("cluster needs at least one worker")
        if not all(w.engine.virtual_clock for w in workers):
            raise ValueError("cluster co-simulation requires virtual-clock "
                             "engines (SimRunner)")
        self.workers = list(workers)
        names = [w.name for w in self.workers]
        if len(set(names)) != len(names):
            raise ValueError(f"worker names must be unique (metrics and "
                             f"migration records key on them): {names}")
        self.cfg = cfg or ClusterConfig()
        self.policy = self.cfg.policy if isinstance(self.cfg.policy,
                                                    RoutingPolicy) \
            else make_policy(self.cfg.policy)
        self.dispatcher = self.cfg.dispatcher \
            if isinstance(self.cfg.dispatcher, DispatchPolicy) \
            else make_dispatcher(self.cfg.dispatcher)
        # runtime-owned observation state: policies get it on the view
        self.straggler = StragglerTracker(alpha=self.cfg.straggler_alpha)
        self.rebalancer = self.cfg.rebalance \
            if isinstance(self.cfg.rebalance, RebalancePolicy) \
            else (make_rebalancer(self.cfg.rebalance)
                  if self.cfg.rebalance is not None else None)
        self._next_rebalance_check = float("-inf")

        self.prefill_pool = [w for w in self.workers if w.role == "prefill"]
        self.decode_pool = [w for w in self.workers if w.role == "decode"]
        self.colocated_pool = [w for w in self.workers
                               if w.role == "colocated"]
        self.disaggregated = bool(self.prefill_pool)
        if self.disaggregated and not self.decode_pool:
            raise ValueError("prefill workers need a decode pool to "
                             "migrate into")
        # new requests land on prefill workers (disaggregated) or on the
        # colocated fleet
        self.route_pool = self.prefill_pool if self.disaggregated \
            else self.colocated_pool
        if not self.route_pool:
            raise ValueError("no routable workers (prefill or colocated)")

        # request ids key allocator tables; migration moves requests between
        # engines, so the whole fleet shares one counter — seeded past any
        # rid an engine already issued before joining the cluster
        start = 1 + max((r for w in self.workers
                         for r in w.engine.issued_rids()), default=-1)
        self._rid_source = itertools.count(start)
        for w in self.workers:
            w.engine.adopt_rid_source(self._rid_source)

        self._arrivals: List = []          # (t, seq, TraceEntry) min-heap
        self._arr_seq = itertools.count()
        self._migrating: List[dict] = []   # in-flight KV transfers
        self._warming: List[Worker] = []   # minted, weight load in progress
        self._retire_requested: Dict[str, float] = {}
        self.autoscaler = autoscaler       # optional AutoscaleController
        self._classes = ClassPolicy(priority=dict(self.cfg.class_priorities))
        # the fleet event stream: every worker engine's stream forwards into
        # it, and the runtime emits its own fleet-level transitions (worker
        # lifecycle, migrations in flight, scaling decisions, run end) with
        # explicit fleet-clock timestamps. ClusterMetrics is a subscriber —
        # its scaling/migration/submitted records are derivations, not a
        # second bookkeeping path.
        self.events = EventLog()
        self.emitter = EventEmitter(self.events, clock=lambda: self.makespan)
        for w in self.workers:
            w.engine.events.subscribe(self.events.emit)
        self.submitted: List[Request] = []
        self.metrics = ClusterMetrics(self.workers, submitted=self.submitted)
        self.events.subscribe(self.metrics.on_event)
        # dynamic invariant checks (repro.lint.sanitizer) every loop
        # iteration; read-only, so metrics stay bit-identical
        self._sanitizer = None
        if sanitize:
            from repro.lint.sanitizer import ClusterSanitizer
            self._sanitizer = ClusterSanitizer()
            self._sanitizer.attach(self)

    # ------------------------------------------------------------------- api
    @property
    def makespan(self) -> float:
        """The fleet clock: the latest worker time (the honest goodput
        denominator — finished-only windows ignore the in-flight tail)."""
        return max(w.engine.now for w in self.workers)

    def submit(self, isl: int, osl: int, arrival: float = 0.0,
               slo_class: str = ""):
        if self.disaggregated:
            cap = max(w.kv_view().capacity_tokens for w in self.decode_pool)
            if isl + osl + 1 > cap:
                raise ValueError(f"request ({isl} in, {osl} out) exceeds "
                                 f"largest decode-pool KV capacity {cap}")
            pcap = max(w.kv_view().capacity_tokens for w in self.prefill_pool)
            if isl + 2 > pcap:
                raise ValueError(f"request prompt ({isl} tokens) exceeds "
                                 f"largest prefill-pool KV capacity {pcap}")
        else:
            cap = max(w.kv_view().capacity_tokens for w in self.route_pool)
            if isl + osl + 1 > cap:
                raise ValueError(f"request ({isl} in, {osl} out) exceeds "
                                 f"largest worker KV capacity {cap}")
        heapq.heappush(self._arrivals,
                       (arrival, next(self._arr_seq),
                        TraceEntry(arrival, isl, osl, slo_class)))

    def submit_trace(self, trace: Sequence[TraceEntry]):
        for e in trace:
            self.submit(e.isl, e.osl, e.arrival, slo_class=e.slo_class)

    # ---------------------------------------------------------- decision plane
    def fleet_view(self, t: Optional[float] = None, *,
                   series: bool = True) -> FleetView:
        """One frozen, read-only observation of the whole fleet — what the
        autoscaler and the rebalancer decide on (``repro.cluster.view``)."""
        return fleet_snapshot(self, t=t, series=series)

    # ------------------------------------------------------------- elasticity
    def _role_pool(self, role: str) -> List[Worker]:
        return {"prefill": self.prefill_pool, "decode": self.decode_pool,
                "colocated": self.colocated_pool}[role]

    def active_pool(self, role: str) -> List[Worker]:
        """The routable/dispatchable replicas of a role — excludes warming
        (weight load in progress) and draining workers. What a scaling
        policy sizes."""
        return list(self._role_pool(role))

    def warming_count(self, role: str) -> int:
        return sum(1 for w in self._warming if w.role == role)

    def add_worker(self, worker: Worker, at: Optional[float] = None,
                   cold_start_extra_s: float = 0.0) -> float:
        """Mint a replica mid-run. The worker is provisioned (and paid for,
        in worker-seconds) from ``at``, but joins its route/dispatch pool
        only after the modeled cold start: weight-shard load into HBM
        (``pm.weight_load_time``) plus ``cold_start_extra_s`` for checkpoint
        fetch / container spin-up. Returns the pool-entry time."""
        if any(w.name == worker.name for w in self.workers):
            raise ValueError(f"worker name {worker.name!r} already in fleet")
        if not worker.engine.virtual_clock:
            raise ValueError("cluster co-simulation requires virtual-clock "
                             "engines (SimRunner)")
        if worker.role == "prefill" and not self.disaggregated:
            raise ValueError("cannot add a prefill worker to a colocated "
                             "fleet (no decode pool to migrate into)")
        t = self.makespan if at is None else at
        r = worker.engine.runner
        load = pm.weight_load_time(worker.engine.cfg_model, r.plan, r.hw,
                                   r.dtype_bytes) + cold_start_extra_s
        worker.t_join = t
        worker.t_active = t + load
        worker.engine.adopt_rid_source(self._rid_source)
        self.workers.append(worker)
        self._warming.append(worker)
        # forward the minted engine's stream into the fleet log BEFORE the
        # mint event — its first engine event must not beat its lifecycle
        worker.engine.events.subscribe(self.events.emit)
        self.emitter.emit("mint", t=t, worker=worker.name, ref=worker,
                          role=worker.role, load_s=load,
                          pool_size=len(self._role_pool(worker.role)))
        return worker.t_active

    def retire_worker(self, worker: Optional[Worker] = None,
                      role: str = "colocated",
                      at: Optional[float] = None) -> Worker:
        """Gracefully retire a replica: it leaves the route/dispatch pools
        immediately (no new routes, dispatches or arrivals land on it) but
        keeps stepping until its in-flight requests finish; the drain
        completion stamps ``Worker.t_retire`` (never earlier than the
        retirement request) so per-worker accounting stays honest. With no
        explicit ``worker``, the emptiest replica of ``role`` is chosen
        (fastest drain)."""
        if worker is None:
            pool = self._role_pool(role)
            if not pool:
                raise ValueError(f"no active {role!r} workers to retire")
            vs = [snapshot(w) for w in pool]
            worker = pool[min(range(len(pool)),
                              key=lambda i: (vs[i].queue_depth,
                                             vs[i].kv_util))]
        pool = self._role_pool(worker.role)
        if worker not in pool:
            raise ValueError(f"worker {worker.name!r} is not in the active "
                             f"{worker.role!r} pool")
        if pool is self.route_pool and len(pool) == 1:
            raise ValueError("cannot retire the last routable worker")
        if pool is self.decode_pool and self.disaggregated and len(pool) == 1:
            raise ValueError("cannot retire the last decode worker of a "
                             "disaggregated fleet (migrations would wedge)")
        pool.remove(worker)
        worker.draining = True
        t = worker.engine.now if at is None else at
        self._retire_requested[worker.name] = t
        # an idle retiree has no drain to wait for: its clock may lag the
        # fleet (idle engines only advance on work) — bring it to the
        # decommission decision time before it goes dark
        if not worker.engine.has_work:
            worker.engine.advance_to(t)
        self.emitter.emit("retire", t=t, worker=worker.name, ref=worker,
                          role=worker.role, pool_size=len(pool))
        self._finish_retirements()
        return worker

    def _finish_retirements(self):
        for w in self.workers:
            if w.draining and w.t_retire is None and not w.engine.has_work:
                w.t_retire = max(w.engine.now,
                                 self._retire_requested.get(w.name, 0.0))
                # a reused name must not inherit the retiree's straggle EWMA
                self.straggler.forget(w.name)
                self.emitter.emit(
                    "drained", t=w.t_retire, worker=w.name, ref=w,
                    role=w.role, pool_size=len(self._role_pool(w.role)))

    def _activate_warming(self, upto: float):
        ready = sorted((w for w in self._warming
                        if w.t_active <= upto + 1e-12),
                       key=lambda w: w.t_active)
        for w in ready:
            self._warming.remove(w)
            w.engine.advance_to(w.t_active)
            pool = self._role_pool(w.role)
            pool.append(w)
            self.emitter.emit("join", t=w.t_active, worker=w.name, ref=w,
                              role=w.role, pool_size=len(pool))

    def _next_event_time(self) -> Optional[float]:
        """Earliest upcoming fleet event of any kind — worker actions,
        KV-transfer completions, unrouted arrivals, warming pool entries.
        The controller ticks up to (never past) this time."""
        ts = [t for t in (self._next_action_time(w) for w in self.workers)
              if t is not None]
        ts += [m["ready"] for m in self._migrating]
        ts += [w.t_active for w in self._warming]
        if self._arrivals:
            ts.append(self._arrivals[0][0])
        return min(ts, default=None)

    def _autoscale_ticks(self):
        """Fire every controller tick due before the fleet's next event, in
        order, on the virtual clock. Signal observation reads fleet state
        without advancing any engine clock, so a controller that takes no
        action leaves the simulation bit-identical to the static path."""
        a = self.autoscaler
        while True:
            ne = self._next_event_time()
            if ne is None or a.next_tick is None or a.next_tick > ne:
                return
            t = a.next_tick
            self._activate_warming(t)
            a.tick(self, t)

    def run(self, max_steps: int = 10 ** 7) -> ClusterMetrics:
        for _ in range(max_steps):
            if self.autoscaler is not None:
                self._autoscale_ticks()
            self._deliver_migrations()
            self._route_arrivals()
            if self.rebalancer is not None:
                self._tick_rebalance()
            w = self._next_worker()
            if w is None:
                if self._migrating:
                    # adopter pool saturated and idle: let the retry clock of
                    # the earliest transfer pull the fleet forward — unless
                    # an unrouted arrival is the earlier fleet event (the
                    # work it spawns may land on these idle workers first)
                    t = min(m["ready"] for m in self._migrating)
                    if self._arrivals and self._arrivals[0][0] < t:
                        continue                 # routing releases it next
                    for dw in self._adopter_pool():
                        if not dw.engine.sched.has_work:
                            dw.engine.advance_to(t)
                    self._deliver_migrations()
                    if self._next_worker() is None and not self._arrivals:
                        if self._migrating:      # truly wedged: no KV room
                            raise RuntimeError(
                                f"{len(self._migrating)} migrated requests "
                                "cannot fit any decode worker")
                    continue
                if self._arrivals:
                    continue                     # routing will gate-release
                break                            # fleet drained
            t0 = w.engine.now
            w.engine.step()
            if w in self.route_pool:
                self.straggler.note_step(w.name, w.engine.now - t0)
            if w.role == "prefill":
                self._harvest_prefill_complete(w)
            if w.draining:
                self._finish_retirements()
            if self._sanitizer is not None:
                self._sanitizer.check(self)
        # stamp the fleet makespan (via the stream: ClusterMetrics folds it
        # into t_end) so summaries use the true serving window and can count
        # still-in-flight requests as SLO misses
        self.emitter.emit("run_end", t=self.makespan)
        return self.metrics

    # ------------------------------------------------------------- internals
    def _next_action_time(self, w: Worker) -> Optional[float]:
        if w.engine.sched.has_work:
            return w.engine.now
        nxt = w.engine.next_arrival()
        if nxt is not None:
            return max(w.engine.now, nxt)
        return None

    def _next_worker(self) -> Optional[Worker]:
        best, best_t = None, None
        for w in self.workers:
            t = self._next_action_time(w)
            if t is not None and (best_t is None or t < best_t):
                best, best_t = w, t
        return best

    def _horizon(self) -> Optional[float]:
        """Earliest time anything already in the system acts next."""
        ts = [t for t in (self._next_action_time(w) for w in self.workers)
              if t is not None]
        ts += [m["ready"] for m in self._migrating]
        return min(ts, default=None)

    def _route_arrivals(self):
        while self._arrivals:
            t = self._arrivals[0][0]
            horizon = self._horizon()
            if horizon is not None and t > horizon:
                break                  # the future: in-flight work acts first
            _, _, entry = heapq.heappop(self._arrivals)
            if self._warming:
                # replicas whose cold start completed by this arrival are
                # routable for it
                self._activate_warming(entry.arrival)
            # a fresh view per route decision: the previous route's admission
            # and KV growth must be visible to this one (live-read semantics)
            views = [snapshot(w, straggler=self.straggler)
                     for w in self.route_pool]
            try:
                i = self.policy.pick(
                    views, entry.isl, entry.osl,
                    urgency=self._classes.normalized_urgency(entry.slo_class))
            except NoFeasibleWorker as e:
                raise e.with_context(scenario=self.cfg.name,
                                     arrival=entry.arrival,
                                     slo_class=entry.slo_class) from None
            # the engine's "arrival" event (forwarded into the fleet log)
            # lands the request in self.submitted via ClusterMetrics
            self.route_pool[i].engine.submit(
                entry.isl, entry.osl, arrival=entry.arrival,
                slo_class=entry.slo_class)

    def _harvest_prefill_complete(self, w: Worker):
        done = [r for r in w.engine.sched.running
                if r.prefill_done and r.generated >= 1]
        for req in done:
            w.engine.eject(req)
            hw = w.engine.runner.hw
            tt = pm.kv_transfer_time(w.engine.cfg_model, req.context_len, hw,
                                     self.cfg.transfer_dtype_bytes)
            self._migrating.append({
                "req": req, "src": w.name,
                "eject": w.engine.now, "ready": w.engine.now + tt,
            })
            # migration in flight: the pairing "inject" on the adopter closes
            # the MigrationRecord in ClusterMetrics
            self.emitter.emit("kv_transfer", rid=req.rid, ref=req,
                              t=w.engine.now, worker=w.name,
                              ready=w.engine.now + tt,
                              context_tokens=req.context_len)

    def _deliver_migrations(self):
        pending = sorted(self._migrating, key=lambda m: m["ready"])
        still = []
        while pending:
            m = pending.pop(0)
            req, ready = m["req"], m["ready"]
            # Delivering to an idle worker fast-forwards its clock to the
            # transfer completion — only allowed when that completion is the
            # fleet's NEXT event. The horizon is recomputed after every
            # delivery (adopting an earlier transfer advances the target's
            # clock and queues work on it, moving the fleet's next event) and
            # counts events engines can't see yet: transfers still awaiting a
            # slot this pass and unrouted arrivals — either can spawn an
            # earlier delivery to this idle worker, and a stale horizon would
            # burn the idle time that delivery should have used.
            hz = min([t for t in (self._next_action_time(w)
                                  for w in self.workers) if t is not None]
                     + [p["ready"] for p in pending]
                     + [s["ready"] for s in still]
                     + ([self._arrivals[0][0]] if self._arrivals else []),
                     default=float("inf"))
            remaining = req.max_new_tokens - req.generated
            # rebalance transfers are pinned to the destination the policy
            # chose; if it retired while the KV was in flight, fall back to
            # any peer but the (pressured) source
            cands = self._adopter_pool()
            pin = m.get("dst")
            if pin is not None:
                pinned = [dw for dw in cands if dw.name == pin]
                if not pinned:
                    pinned = [dw for dw in cands if dw.name != m["src"]]
                cands = pinned or cands
            views = [snapshot(dw, straggler=self.straggler) for dw in cands]
            eligible = [i for i, v in enumerate(views)
                        if req.context_len + remaining + 1
                        <= v.capacity_tokens
                        and (v.now >= ready
                             or (ready <= hz and not v.sched_has_work))]
            urgency = self._classes.normalized_urgency(req.slo_class)
            j = self.dispatcher.pick([views[i] for i in eligible], req,
                                     urgency=urgency) if eligible else None
            if j is None:
                still.append(m)
                continue
            target = cands[eligible[j]]
            target.engine.advance_to(ready)
            if not target.engine.inject(req):
                still.append(m)        # no KV/seq room yet: retry next tick
                continue
            # the adopter's "inject" event (just forwarded into the fleet
            # log) paired with the pending "kv_transfer" closes the
            # MigrationRecord in ClusterMetrics — no separate note here
        self._migrating = still

    def _adopter_pool(self) -> List[Worker]:
        """Who can adopt an in-flight migration: the decode pool, or — for
        decode→decode rebalancing on a colocated fleet — the colocated pool
        (disaggregated fleets always have a decode pool)."""
        return self.decode_pool if self.decode_pool else self.colocated_pool

    # ------------------------------------------------------------- rebalancing
    def _tick_rebalance(self):
        """Consult the rebalance policy on a fresh fleet view, rate-limited
        to ``cfg.rebalance_every_s`` of virtual time (the policy itself
        additionally enforces its decision cooldown)."""
        t = self.makespan
        if t < self._next_rebalance_check:
            return
        self._next_rebalance_check = t + self.cfg.rebalance_every_s
        decision = self.rebalancer.decide(self.fleet_view(t, series=False))
        if decision is not None:
            self._apply_rebalance(decision)

    def _apply_rebalance(self, d):
        """Actuate one RebalanceDecision: emit the ``rebalance`` event, eject
        the victim from the source, pay the modeled KV transfer, and enqueue
        a destination-pinned migration. Decisions are made on a frozen view;
        any that no longer match live state (victim finished, was preempted,
        or moved) are dropped — deciding is cheap, acting on stale state is
        not."""
        by_name = {w.name: w for w in self.workers}
        src, dst = by_name.get(d.src), by_name.get(d.dst)
        if src is None or dst is None or dst.draining:
            return
        req = next((r for r in src.engine.sched.running
                    if r.rid == d.rid), None)
        if req is None or not req.prefill_done or req.generated < 1:
            return
        t = src.engine.now
        self.emitter.emit("rebalance", rid=req.rid, ref=req, t=t,
                          worker=d.src, src=d.src, dst=d.dst,
                          kv_util=d.kv_util, reason=d.reason)
        src.engine.eject(req)
        hw = src.engine.runner.hw
        tt = pm.kv_transfer_time(src.engine.cfg_model, req.context_len, hw,
                                 self.cfg.transfer_dtype_bytes)
        self._migrating.append({
            "req": req, "src": d.src, "eject": t, "ready": t + tt,
            "dst": d.dst, "rebalance": True,
        })
        self.emitter.emit("kv_transfer", rid=req.rid, ref=req, t=t,
                          worker=d.src, ready=t + tt,
                          context_tokens=req.context_len)
