"""A cluster worker: one `InferenceEngine` plus its fleet role.

Roles (paper §III phase divergence / disaggregated serving):
  colocated — runs chunked prefill and decode interleaved (the baseline the
              paper characterises; prefill chunks inflate decode TPOT).
  prefill   — runs prefill only; a request is migrated out right after its
              first token (its KV ships to a decode worker).
  decode    — receives migrated prefill-complete requests and decodes them
              to completion; never executes prefill.

Workers are state holders: the KV-headroom predictions the routing policies
score with live on the decision plane (``repro.cluster.view.WorkerView`` —
the same predicted-peak estimate KV-aware admission uses, Obs 1/8, so the
router and the admission controller agree about saturation); a worker only
exposes the raw accessors the view builder snapshots from.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional

from repro.configs.base import ModelConfig
from repro.core import perf_model as pm
from repro.core.engine import EngineConfig, InferenceEngine
from repro.core.kv_cache import KVView
from repro.core.runner import SimRunner

ROLES = ("colocated", "prefill", "decode")

# auto-name sequence for unnamed workers: a module-level monotonic counter.
# (The old id(engine)&0xffff scheme could collide after GC id-reuse — and
# did, once the autoscaler minted workers in a loop — tripping the runtime's
# unique-name check.)
_WORKER_SEQ = itertools.count()


@dataclasses.dataclass
class Worker:
    engine: InferenceEngine
    role: str = "colocated"
    name: str = ""
    # elasticity lifecycle (static fleets keep the zero-defaults):
    #   t_join   — when the replica was minted (autoscale decision time; the
    #              worker-second meter starts here — cold start is paid for)
    #   t_active — when it entered the route/dispatch pools (join + weight
    #              load); equals t_join for workers present at t=0
    #   t_retire — decommission stamp once a drained retiree goes dark
    #   draining — retired from the pools, finishing its in-flight requests
    t_join: float = 0.0
    t_active: float = 0.0
    t_retire: Optional[float] = None
    draining: bool = False

    def __post_init__(self):
        if self.role not in ROLES:
            raise ValueError(f"unknown worker role {self.role!r}")
        if not self.name:
            self.name = f"{self.role}-{next(_WORKER_SEQ):04d}"
        # stamp the worker name onto the engine's event stream so fleet-level
        # consumers (ClusterMetrics, the sanitizer, trace JSONL) can attribute
        # every engine event to its replica
        self.engine.emitter.worker = self.name

    def active_window(self, t_end: float, t0: float = 0.0) -> float:
        """Seconds this worker was provisioned within [t0, t_end] — the
        per-worker slice of the fleet's worker-second cost (cold start
        included: the meter runs from minting, not from pool entry)."""
        end = self.t_retire if self.t_retire is not None else t_end
        return max(min(end, t_end) - max(self.t_join, t0), 0.0)

    # ------------------------------------------------------------ state views
    @property
    def now(self) -> float:
        return self.engine.now

    @property
    def has_work(self) -> bool:
        return self.engine.has_work

    @property
    def queue_depth(self) -> int:
        s = self.engine.sched
        return len(s.waiting) + len(s.running)

    def kv_util(self) -> float:
        return self.engine.alloc.utilization()

    def kv_view(self) -> KVView:
        """Frozen KV occupancy/capacity snapshot — what the runtime's
        structural capacity checks read instead of allocator internals. The
        full decision-plane snapshot (predicted headroom, queue composition,
        straggler EWMA) is ``repro.cluster.view.snapshot(worker)``."""
        return KVView.of(self.engine.alloc)


def default_admission(role: str) -> str:
    """Prefill workers admit naively (their requests never grow KV —
    predicting decode growth there would starve the pool), everyone else
    uses KV-aware admission (Obs 1/8)."""
    return "naive" if role == "prefill" else "kv_aware"


def default_n_pages(cfg: ModelConfig, plan: pm.ParallelismPlan,
                    hw: pm.Hardware, dtype_bytes: int = 2,
                    page_size: int = 16, cache_dtype_bytes: int = 2) -> int:
    """Paper-calibrated page pool: every KV token that fits after weights +
    runtime overhead. The single source of capacity truth shared by
    `make_sim_worker` and the Scenario compilers."""
    cap = pm.kv_capacity_tokens(cfg, plan, hw, dtype_bytes,
                                cache_dtype_bytes=cache_dtype_bytes)
    return max(cap // page_size, 64)


def make_sim_worker(cfg: ModelConfig, plan: pm.ParallelismPlan,
                    hw: pm.Hardware = pm.H200, *, role: str = "colocated",
                    name: str = "", n_pages: Optional[int] = None,
                    page_size: int = 16, max_seqs: int = 256,
                    max_batched_tokens: int = 8192,
                    chunk_size: int = 512, admission: Optional[str] = None,
                    autotune: bool = False, dtype_bytes: int = 2,
                    cache_dtype_bytes: int = 2, rid_source=None,
                    class_priorities: Optional[Dict[str, int]] = None,
                    class_kv_headroom: float = 0.0,
                    sanitize: bool = False) -> Worker:
    """Virtual-clock worker with paper-calibrated capacity and role-default
    admission (see `default_n_pages` / `default_admission`).
    ``class_priorities``/``class_kv_headroom`` enable multi-tenant SLO-class
    scheduling (urgent classes jump the queue and keep a KV slice)."""
    if n_pages is None:
        n_pages = default_n_pages(cfg, plan, hw, dtype_bytes, page_size,
                                  cache_dtype_bytes)
    if admission is None:
        admission = default_admission(role)
    ecfg = EngineConfig(n_pages=n_pages, page_size=page_size,
                        max_num_seqs=max_seqs,
                        max_num_batched_tokens=max_batched_tokens,
                        chunk_size=chunk_size, admission_mode=admission,
                        autotune=autotune, prefill_only=role == "prefill",
                        class_priorities=dict(class_priorities or {}),
                        class_kv_headroom=class_kv_headroom,
                        sanitize=sanitize)
    eng = InferenceEngine(cfg, ecfg, SimRunner(cfg, plan, hw, dtype_bytes),
                          rid_source=rid_source)
    return Worker(engine=eng, role=role, name=name)
