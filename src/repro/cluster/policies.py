"""Pluggable cluster scheduling policies.

``RoutingPolicy``   — picks a worker for a *new* request (colocated fleets and
                      the prefill pool of a disaggregated fleet).
``DispatchPolicy``  — picks a decode worker for a *migrated* prefill-complete
                      request in a disaggregated fleet.

The memory-aware policy is the paper's Obs 3/4 recommendation ("DP should be
combined with ... memory-aware routing"; "tail latency is dominated by the
replica that reaches KV saturation first"): score replicas by predicted KV
headroom with a straggler penalty folded into one scalar — a replica whose
EWMA step latency runs above the fleet mean is charged a headroom-fraction
equivalent, so slowness and saturation trade off in the same unit.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.request import Request
from repro.cluster.worker import Worker


def pool_capacity_tokens(w: Worker) -> int:
    return w.engine.alloc.n_pages * w.engine.alloc.page_size


def fits_worker(w: Worker, prompt_len: int, max_new: int) -> bool:
    """Hard KV-capacity feasibility: a prefill-only worker needs just the
    prompt (+first token) to fit; everyone else needs the full context."""
    prefill_only = w.engine.sched.cfg.prefill_only
    need = prompt_len + (1 if prefill_only else max_new) + 1
    return need <= pool_capacity_tokens(w)


def eligible_indices(workers: List[Worker], prompt_len: int,
                     max_new: int) -> List[int]:
    """Workers that can hold the request at all — policies must not route to
    a worker whose pool is structurally too small (heterogeneous fleets), or
    the engine's fits-alone invariant breaks mid-run."""
    idx = [i for i, w in enumerate(workers)
           if fits_worker(w, prompt_len, max_new)]
    if not idx:
        raise ValueError(
            f"no worker can hold a ({prompt_len} in, {max_new} out) request"
            f" (pool capacities: {[pool_capacity_tokens(w) for w in workers]})")
    return idx


class RoutingPolicy:
    """Chooses the worker index for a new request."""

    def pick(self, workers: List[Worker], prompt_len: int,
             max_new: int) -> int:
        raise NotImplementedError

    def note_step(self, i: int, dt: float):
        """Observe one engine iteration of worker i (straggler tracking)."""


class RoundRobin(RoutingPolicy):
    def __init__(self):
        self._rr = -1

    def pick(self, workers, prompt_len, max_new):
        ok = set(eligible_indices(workers, prompt_len, max_new))
        for step in range(1, len(workers) + 1):
            i = (self._rr + step) % len(workers)
            if i in ok:
                self._rr = i
                return i
        raise AssertionError("unreachable: eligible_indices is non-empty")


class JoinShortestQueue(RoutingPolicy):
    def pick(self, workers, prompt_len, max_new):
        return min(eligible_indices(workers, prompt_len, max_new),
                   key=lambda i: workers[i].queue_depth)


@dataclasses.dataclass
class MemoryAware(RoutingPolicy):
    """score_i = -headroom_frac_i + straggler_penalty * (lat_i/mean - 1).

    Both terms are dimensionless: headroom as a fraction of the page pool,
    straggle as relative EWMA step latency. The old implementation kept the
    straggler term in the second slot of a tuple key, where it only ever
    broke exact-headroom ties."""
    straggler_penalty: float = 2.0
    ewma_alpha: float = 0.2

    def __post_init__(self):
        self._lat_ewma: List[float] = []

    def note_step(self, i: int, dt: float):
        while len(self._lat_ewma) <= i:
            self._lat_ewma.append(0.0)
        a = self.ewma_alpha
        self._lat_ewma[i] = (1 - a) * self._lat_ewma[i] + a * dt

    def _straggle(self, i: int) -> float:
        if i >= len(self._lat_ewma):
            return 0.0
        mean = sum(self._lat_ewma) / len(self._lat_ewma)
        if mean <= 0:
            return 0.0
        return self._lat_ewma[i] / mean - 1.0

    def pick(self, workers, prompt_len, max_new):
        def score(i):
            w = workers[i]
            head = w.predicted_headroom_pages() \
                - w.predicted_candidate_pages(prompt_len, max_new)
            frac = head / max(w.engine.alloc.n_pages, 1)
            return -frac + self.straggler_penalty * self._straggle(i)
        return min(eligible_indices(workers, prompt_len, max_new), key=score)


def make_policy(name: str, **kw) -> RoutingPolicy:
    table = {"round_robin": RoundRobin, "jsq": JoinShortestQueue,
             "memory_aware": MemoryAware}
    if name not in table:
        raise ValueError(f"unknown routing policy {name!r} "
                         f"(have {sorted(table)})")
    return table[name](**kw)


# ---------------------------------------------------------------- dispatchers
class DispatchPolicy:
    """Chooses the decode worker that adopts a migrated request."""

    def pick(self, workers: List[Worker], req: Request) -> Optional[int]:
        raise NotImplementedError


class LeastKVHeadroom(DispatchPolicy):
    """Best-fit decode dispatch: among decode workers whose predicted
    headroom still fits the request's remaining growth, pick the one with the
    LEAST headroom — packing tight keeps the emptiest replica free for the
    long-decode tail (the requests that actually hit the capacity wall,
    Obs 4). Falls back to the most-headroom worker when none fits."""

    def pick(self, workers, req):
        if not workers:
            return None
        need = [None] * len(workers)
        fits = []
        for i, w in enumerate(workers):
            remaining = req.max_new_tokens - req.generated
            pages = w.engine.alloc.pages_for(req.context_len + remaining + 1)
            head = w.predicted_headroom_pages()
            need[i] = head
            if head >= pages:
                fits.append(i)
        if fits:
            return min(fits, key=lambda i: need[i])
        return max(range(len(workers)), key=lambda i: need[i])


class MostKVHeadroom(DispatchPolicy):
    """Worst-fit (load-levelling) decode dispatch: always the emptiest."""

    def pick(self, workers, req):
        if not workers:
            return None
        return max(range(len(workers)),
                   key=lambda i: workers[i].predicted_headroom_pages())


def make_dispatcher(name: str) -> DispatchPolicy:
    table = {"least_headroom": LeastKVHeadroom,
             "most_headroom": MostKVHeadroom}
    if name not in table:
        raise ValueError(f"unknown dispatch policy {name!r} "
                         f"(have {sorted(table)})")
    return table[name]()
