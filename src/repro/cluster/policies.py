"""Pluggable cluster scheduling policies, scored on the decision plane.

``RoutingPolicy``   — picks a worker for a *new* request (colocated fleets and
                      the prefill pool of a disaggregated fleet).
``DispatchPolicy``  — picks a decode worker for a *migrated* prefill-complete
                      request in a disaggregated fleet.

Policies consume frozen :class:`~repro.cluster.view.WorkerView` snapshots,
never live workers: all KV headroom / occupancy / feasibility math lives in
``repro.cluster.view`` (lint rule REP010 rejects ``engine``/``alloc``/
``sched`` access here), so routing, dispatch, admission and autoscaling
reason from one consistent observation instead of six ad-hoc re-derivations.

The memory-aware policy is the paper's Obs 3/4 recommendation ("DP should be
combined with ... memory-aware routing"; "tail latency is dominated by the
replica that reaches KV saturation first"): score replicas by predicted KV
headroom with a straggler penalty folded into one scalar — a replica whose
EWMA step latency runs above the fleet mean is charged a headroom-fraction
equivalent, so slowness and saturation trade off in the same unit. The
straggler EWMA itself is runtime-owned (``StragglerTracker``) and arrives on
the view as ``step_ewma``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.request import Request
from repro.cluster.view import WorkerView, eligible_indices


class RoutingPolicy:
    """Chooses the worker index for a new request. ``urgency`` is the
    request's SLO-class urgency normalised to [0, 1] (0 = batch/untiered) —
    class-aware policies may weigh latency risk more heavily for urgent
    requests; class-blind policies ignore it."""

    def pick(self, views: List[WorkerView], prompt_len: int,
             max_new: int, urgency: float = 0.0) -> int:
        raise NotImplementedError


class RoundRobin(RoutingPolicy):
    def __init__(self):
        self._rr = -1

    def pick(self, views: List[WorkerView], prompt_len: int,
             max_new: int, urgency: float = 0.0) -> int:
        ok = set(eligible_indices(views, prompt_len, max_new))
        for step in range(1, len(views) + 1):
            i = (self._rr + step) % len(views)
            if i in ok:
                self._rr = i
                return i
        raise AssertionError("unreachable: eligible_indices is non-empty")


class JoinShortestQueue(RoutingPolicy):
    def pick(self, views: List[WorkerView], prompt_len: int,
             max_new: int, urgency: float = 0.0) -> int:
        return min(eligible_indices(views, prompt_len, max_new),
                   key=lambda i: views[i].queue_depth)


def relative_straggle(v: WorkerView,
                      pool: List[WorkerView]) -> float:
    """Relative EWMA step latency of ``v`` among the *observed* members of
    ``pool`` (its own view included): EWMA / pool-observed-mean - 1. Workers
    never observed carry no data, take no penalty and no reward, and do not
    drag the reference mean — the PR-3 warmup-bias fix, now expressed on
    view fields."""
    if v.step_ewma is None:
        return 0.0
    observed = [u.step_ewma for u in pool if u.step_ewma is not None]
    if not observed:
        return 0.0
    mean = sum(observed) / len(observed)
    if mean <= 0:
        return 0.0
    return v.step_ewma / mean - 1.0


@dataclasses.dataclass
class MemoryAware(RoutingPolicy):
    """score_i = -headroom_frac_i + straggler_penalty * straggle_i
               + urgency_weight * urgency * queue_frac_i.

    All terms are dimensionless: headroom as a fraction of the page pool,
    straggle as relative EWMA step latency among *observed* workers
    (``relative_straggle``), queue pressure as occupancy of the concurrency
    cap. The urgency term makes the router latency-averse for interactive
    requests (a deep queue is TTFT risk) while batch requests still pack by
    headroom."""
    straggler_penalty: float = 2.0
    urgency_weight: float = 1.0

    def pick(self, views: List[WorkerView], prompt_len: int,
             max_new: int, urgency: float = 0.0) -> int:
        def score(i):
            v = views[i]
            head = v.predicted_headroom_pages() \
                - v.candidate_pages(prompt_len, max_new)
            frac = head / max(v.n_pages, 1)
            queue_frac = v.queue_depth / max(v.max_seqs, 1)
            return (-frac
                    + self.straggler_penalty * relative_straggle(v, views)
                    + self.urgency_weight * urgency * queue_frac)
        return min(eligible_indices(views, prompt_len, max_new), key=score)


def make_policy(name: str, **kw) -> RoutingPolicy:
    table = {"round_robin": RoundRobin, "jsq": JoinShortestQueue,
             "memory_aware": MemoryAware}
    if name not in table:
        raise ValueError(f"unknown routing policy {name!r} "
                         f"(have {sorted(table)})")
    return table[name](**kw)


# ---------------------------------------------------------------- dispatchers
class DispatchPolicy:
    """Chooses the decode worker that adopts a migrated request. ``urgency``
    is the request's normalised SLO-class urgency (see RoutingPolicy)."""

    def pick(self, views: List[WorkerView], req: Request,
             urgency: float = 0.0) -> Optional[int]:
        raise NotImplementedError


class LeastKVHeadroom(DispatchPolicy):
    """Best-fit decode dispatch: among decode workers whose predicted
    headroom still fits the request's remaining growth, pick the one with the
    LEAST headroom — packing tight keeps the emptiest replica free for the
    long-decode tail (the requests that actually hit the capacity wall,
    Obs 4). Urgent (interactive) requests instead pick the least *loaded*
    fitting worker — a packed replica's batch depth is TPOT risk, and their
    short decodes never stress the capacity wall best-fit protects. Falls
    back to the most-headroom worker when none fits."""

    def pick(self, views: List[WorkerView], req: Request,
             urgency: float = 0.0) -> Optional[int]:
        if not views:
            return None
        need = [None] * len(views)
        fits = []
        for i, v in enumerate(views):
            remaining = req.max_new_tokens - req.generated
            pages = v.pages_for(req.context_len + remaining + 1)
            head = v.predicted_headroom_pages()
            need[i] = head
            if head >= pages:
                fits.append(i)
        if fits:
            if urgency > 0.5:
                return min(fits, key=lambda i: (views[i].queue_depth,
                                                need[i]))
            return min(fits, key=lambda i: need[i])
        return max(range(len(views)), key=lambda i: need[i])


class MostKVHeadroom(DispatchPolicy):
    """Worst-fit (load-levelling) decode dispatch: always the emptiest."""

    def pick(self, views: List[WorkerView], req: Request,
             urgency: float = 0.0) -> Optional[int]:
        if not views:
            return None
        return max(range(len(views)),
                   key=lambda i: views[i].predicted_headroom_pages())


def make_dispatcher(name: str) -> DispatchPolicy:
    table = {"least_headroom": LeastKVHeadroom,
             "most_headroom": MostKVHeadroom}
    if name not in table:
        raise ValueError(f"unknown dispatch policy {name!r} "
                         f"(have {sorted(table)})")
    return table[name]()
