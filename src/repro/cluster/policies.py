"""Pluggable cluster scheduling policies.

``RoutingPolicy``   — picks a worker for a *new* request (colocated fleets and
                      the prefill pool of a disaggregated fleet).
``DispatchPolicy``  — picks a decode worker for a *migrated* prefill-complete
                      request in a disaggregated fleet.

The memory-aware policy is the paper's Obs 3/4 recommendation ("DP should be
combined with ... memory-aware routing"; "tail latency is dominated by the
replica that reaches KV saturation first"): score replicas by predicted KV
headroom with a straggler penalty folded into one scalar — a replica whose
EWMA step latency runs above the fleet mean is charged a headroom-fraction
equivalent, so slowness and saturation trade off in the same unit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.request import Request
from repro.cluster.worker import Worker


def pool_capacity_tokens(w: Worker) -> int:
    return w.engine.alloc.n_pages * w.engine.alloc.page_size


def fits_worker(w: Worker, prompt_len: int, max_new: int) -> bool:
    """Hard KV-capacity feasibility: a prefill-only worker needs just the
    prompt (+first token) to fit; everyone else needs the full context."""
    prefill_only = w.engine.sched.cfg.prefill_only
    need = prompt_len + (1 if prefill_only else max_new) + 1
    return need <= pool_capacity_tokens(w)


def eligible_indices(workers: List[Worker], prompt_len: int,
                     max_new: int) -> List[int]:
    """Workers that can hold the request at all — policies must not route to
    a worker whose pool is structurally too small (heterogeneous fleets), or
    the engine's fits-alone invariant breaks mid-run."""
    idx = [i for i, w in enumerate(workers)
           if fits_worker(w, prompt_len, max_new)]
    if not idx:
        raise ValueError(
            f"no worker can hold a ({prompt_len} in, {max_new} out) request"
            f" (pool capacities: {[pool_capacity_tokens(w) for w in workers]})")
    return idx


class RoutingPolicy:
    """Chooses the worker index for a new request. ``urgency`` is the
    request's SLO-class urgency normalised to [0, 1] (0 = batch/untiered) —
    class-aware policies may weigh latency risk more heavily for urgent
    requests; class-blind policies ignore it."""

    def pick(self, workers: List[Worker], prompt_len: int,
             max_new: int, urgency: float = 0.0) -> int:
        raise NotImplementedError

    def note_step(self, name: str, dt: float):
        """Observe one engine iteration of the named worker (straggler
        tracking). Keyed by worker *name*, not pool index — autoscaling
        mutates the pool, and an index-keyed EWMA would silently transfer a
        retired worker's latency history to whichever replica inherited its
        slot."""


class RoundRobin(RoutingPolicy):
    def __init__(self):
        self._rr = -1

    def pick(self, workers: List[Worker], prompt_len: int,
             max_new: int, urgency: float = 0.0) -> int:
        ok = set(eligible_indices(workers, prompt_len, max_new))
        for step in range(1, len(workers) + 1):
            i = (self._rr + step) % len(workers)
            if i in ok:
                self._rr = i
                return i
        raise AssertionError("unreachable: eligible_indices is non-empty")


class JoinShortestQueue(RoutingPolicy):
    def pick(self, workers: List[Worker], prompt_len: int,
             max_new: int, urgency: float = 0.0) -> int:
        return min(eligible_indices(workers, prompt_len, max_new),
                   key=lambda i: workers[i].queue_depth)


@dataclasses.dataclass
class MemoryAware(RoutingPolicy):
    """score_i = -headroom_frac_i + straggler_penalty * straggle_i
               + urgency_weight * urgency * queue_frac_i.

    All terms are dimensionless: headroom as a fraction of the page pool,
    straggle as relative EWMA step latency among *observed* workers, queue
    pressure as occupancy of the concurrency cap. The urgency term makes the
    router latency-averse for interactive requests (a deep queue is TTFT
    risk) while batch requests still pack by headroom.

    Straggler state is keyed by worker NAME so it survives pool mutation
    (autoscaled fleets add and retire replicas mid-run; an index-keyed list
    would hand a retiree's history to its slot's inheritor). Only observed
    workers carry data: unobserved workers take no penalty and no reward,
    and the fleet mean is computed over the *current pool's* observed
    members — a long-retired straggler must not drag the reference mean."""
    straggler_penalty: float = 2.0
    ewma_alpha: float = 0.2
    urgency_weight: float = 1.0

    def __post_init__(self):
        self._lat_ewma: Dict[str, float] = {}

    def note_step(self, name: str, dt: float):
        prev = self._lat_ewma.get(name)
        a = self.ewma_alpha
        # first observation seeds the EWMA (no bias toward zero at warmup)
        self._lat_ewma[name] = dt if prev is None else (1 - a) * prev + a * dt

    def forget(self, name: str):
        """Drop a retired worker's history (a future replica reusing the
        name must not inherit a dead worker's straggle)."""
        self._lat_ewma.pop(name, None)

    def _straggle(self, name: str,
                  pool: Optional[Sequence[str]] = None) -> float:
        """Relative EWMA step latency of ``name`` among the observed members
        of ``pool`` (default: every observed worker)."""
        if name not in self._lat_ewma:
            return 0.0                   # unobserved: no data, no penalty
        names = list(pool) if pool is not None else list(self._lat_ewma)
        observed = [self._lat_ewma[n] for n in names if n in self._lat_ewma]
        if not observed:
            return 0.0
        mean = sum(observed) / len(observed)
        if mean <= 0:
            return 0.0
        return self._lat_ewma[name] / mean - 1.0

    def pick(self, workers: List[Worker], prompt_len: int,
             max_new: int, urgency: float = 0.0) -> int:
        pool_names = [w.name for w in workers]

        def score(i):
            w = workers[i]
            head = w.predicted_headroom_pages() \
                - w.predicted_candidate_pages(prompt_len, max_new)
            frac = head / max(w.engine.alloc.n_pages, 1)
            queue_frac = w.queue_depth / max(w.engine.sched.cfg.max_num_seqs,
                                             1)
            return (-frac
                    + self.straggler_penalty * self._straggle(w.name,
                                                              pool_names)
                    + self.urgency_weight * urgency * queue_frac)
        return min(eligible_indices(workers, prompt_len, max_new), key=score)


def make_policy(name: str, **kw) -> RoutingPolicy:
    table = {"round_robin": RoundRobin, "jsq": JoinShortestQueue,
             "memory_aware": MemoryAware}
    if name not in table:
        raise ValueError(f"unknown routing policy {name!r} "
                         f"(have {sorted(table)})")
    return table[name](**kw)


# ---------------------------------------------------------------- dispatchers
class DispatchPolicy:
    """Chooses the decode worker that adopts a migrated request. ``urgency``
    is the request's normalised SLO-class urgency (see RoutingPolicy)."""

    def pick(self, workers: List[Worker], req: Request,
             urgency: float = 0.0) -> Optional[int]:
        raise NotImplementedError


class LeastKVHeadroom(DispatchPolicy):
    """Best-fit decode dispatch: among decode workers whose predicted
    headroom still fits the request's remaining growth, pick the one with the
    LEAST headroom — packing tight keeps the emptiest replica free for the
    long-decode tail (the requests that actually hit the capacity wall,
    Obs 4). Urgent (interactive) requests instead pick the least *loaded*
    fitting worker — a packed replica's batch depth is TPOT risk, and their
    short decodes never stress the capacity wall best-fit protects. Falls
    back to the most-headroom worker when none fits."""

    def pick(self, workers: List[Worker], req: Request,
             urgency: float = 0.0) -> Optional[int]:
        if not workers:
            return None
        need = [None] * len(workers)
        fits = []
        for i, w in enumerate(workers):
            remaining = req.max_new_tokens - req.generated
            pages = w.engine.alloc.pages_for(req.context_len + remaining + 1)
            head = w.predicted_headroom_pages()
            need[i] = head
            if head >= pages:
                fits.append(i)
        if fits:
            if urgency > 0.5:
                return min(fits, key=lambda i: (workers[i].queue_depth,
                                                need[i]))
            return min(fits, key=lambda i: need[i])
        return max(range(len(workers)), key=lambda i: need[i])


class MostKVHeadroom(DispatchPolicy):
    """Worst-fit (load-levelling) decode dispatch: always the emptiest."""

    def pick(self, workers: List[Worker], req: Request,
             urgency: float = 0.0) -> Optional[int]:
        if not workers:
            return None
        return max(range(len(workers)),
                   key=lambda i: workers[i].predicted_headroom_pages())


def make_dispatcher(name: str) -> DispatchPolicy:
    table = {"least_headroom": LeastKVHeadroom,
             "most_headroom": MostKVHeadroom}
    if name not in table:
        raise ValueError(f"unknown dispatch policy {name!r} "
                         f"(have {sorted(table)})")
    return table[name]()
