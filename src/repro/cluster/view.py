"""The unified decision plane: frozen, read-only fleet state snapshots.

The paper frames navigating the Capacity-Bound regime as a *decision*
problem — memory-aware routing, preemption-storm avoidance (Obs 3/4) and
scaling policy all hinge on an accurate, consistent view of per-replica KV
headroom, queue depth and straggler state. This module is the ONE place
that view is built: a :func:`snapshot` reads an engine's allocator and
scheduler exactly once per decision point and freezes the result into a
:class:`WorkerView`; :func:`fleet_snapshot` assembles the per-role
:class:`FleetView` the autoscaler and the rebalancer consume. Policies
(``repro.cluster.policies``), scaling signals (``repro.cluster.autoscale``)
and rebalancing (``repro.cluster.rebalance``) see ONLY these views — lint
rule REP010 rejects any ``engine``/``alloc``/``sched`` access in those
modules, so headroom math cannot silently fork again.

Views are snapshots, not live handles: construction never mutates engine
state (property-tested under the sim sanitizer), and a view taken before a
state change keeps reporting the old state. Decision sites therefore build
a fresh view per decision (route pop, migration delivery, controller tick),
which matches the live-read semantics the policies had before the refactor
bit for bit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.kv_cache import KVView
from repro.core.request import Request


class NoFeasibleWorker(ValueError):
    """No worker in the candidate pool can structurally hold a request.

    Raised by :func:`eligible_indices` (and surfaced by ``ClusterRuntime``
    with the scenario name attached) instead of a bare ``ValueError``, so an
    infeasible heterogeneous-fleet route aborts with full request context:
    the request's shape, its rid when one was already minted, and every
    candidate's KV capacity."""

    def __init__(self, prompt_len: int, max_new: int,
                 capacities: Sequence[Tuple[str, int]], *,
                 rid: Optional[int] = None, slo_class: str = "",
                 arrival: Optional[float] = None, scenario: str = ""):
        self.prompt_len = prompt_len
        self.max_new = max_new
        self.capacities = tuple(capacities)
        self.rid = rid
        self.slo_class = slo_class
        self.arrival = arrival
        self.scenario = scenario
        super().__init__(self._message())

    def _message(self) -> str:
        who = f"request rid={self.rid}" if self.rid is not None else "request"
        ctx = f" of scenario {self.scenario!r}" if self.scenario else ""
        when = f" arriving at t={self.arrival}" if self.arrival is not None \
            else ""
        cls = f" [class {self.slo_class!r}]" if self.slo_class else ""
        caps = ", ".join(f"{name}={cap}" for name, cap in self.capacities)
        return (f"no worker{ctx} can hold a ({self.prompt_len} in, "
                f"{self.max_new} out) {who}{cls}{when} "
                f"(per-worker KV capacities in tokens: {caps})")

    def with_context(self, *, rid: Optional[int] = None, slo_class: str = "",
                     arrival: Optional[float] = None,
                     scenario: str = "") -> "NoFeasibleWorker":
        """A copy enriched with request/scenario context (the runtime knows
        the scenario name and arrival; the policy that raised does not)."""
        return NoFeasibleWorker(
            self.prompt_len, self.max_new, self.capacities,
            rid=self.rid if rid is None else rid,
            slo_class=self.slo_class or slo_class,
            arrival=self.arrival if arrival is None else arrival,
            scenario=self.scenario or scenario)


@dataclasses.dataclass(frozen=True)
class RequestView:
    """One queued/running request, as victim-choice and rebalancing see it.

    ``urgency`` is the owning engine's raw class urgency (the scheduler's
    preemption-victim currency), so cluster-level migration victim choice
    orders candidates exactly like engine-level preemption does."""
    rid: int
    slo_class: str
    urgency: int
    arrival: float
    isl: int
    generated: int
    context_len: int
    remaining: int                # max_new_tokens - generated
    prefill_done: bool


@dataclasses.dataclass(frozen=True)
class WorkerView:
    """Frozen snapshot of one worker at a decision point.

    Everything a routing/dispatch/rebalance/scaling decision may consult:
    KV occupancy and predicted peak demand, batch occupancy vs the
    concurrency cap, queue depth by SLO class, lifecycle flags, and the
    runtime-tracked straggler EWMA. All derived quantities (headroom,
    feasibility, candidate page demand) are pure functions of the frozen
    fields — reading a view cannot touch the engine it was taken from."""
    name: str
    role: str
    prefill_only: bool
    warming: bool
    draining: bool
    now: float
    has_work: bool                # engine-level: queued work OR gated arrivals
    sched_has_work: bool          # scheduler-level: waiting/running only
    kv: KVView
    kv_util: float
    predicted_used: float         # predicted peak pages of queued+running
    osl_est: float                # admission estimator's current OSL estimate
    n_running: int
    n_waiting: int
    max_seqs: int
    preemptions: int              # cumulative engine preemption count
    step_ewma: Optional[float]    # straggler EWMA (None: never observed)
    waiting_by_class: Tuple[Tuple[str, int], ...]
    running_reqs: Tuple[RequestView, ...]

    # ------------------------------------------------------- pure derivations
    @property
    def n_pages(self) -> int:
        return self.kv.n_pages

    @property
    def page_size(self) -> int:
        return self.kv.page_size

    @property
    def capacity_tokens(self) -> int:
        return self.kv.capacity_tokens

    @property
    def queue_depth(self) -> int:
        return self.n_waiting + self.n_running

    def pages_for(self, tokens: int) -> int:
        return self.kv.pages_for(tokens)

    def fits(self, prompt_len: int, max_new: int) -> bool:
        """Hard KV-capacity feasibility: a prefill-only worker needs just
        the prompt (+first token) to fit; everyone else the full context."""
        need = prompt_len + (1 if self.prefill_only else max_new) + 1
        return need <= self.capacity_tokens

    def predicted_headroom_pages(self) -> float:
        return self.kv.n_pages - self.predicted_used

    def candidate_pages(self, prompt_len: int, max_new: int) -> int:
        """Role-aware page demand of a prospective request: prefill workers
        hold only the prompt (+first token); others grow by the predicted
        OSL — the same accounting ``predicted_used`` applies to what is
        already queued."""
        future = 0
        if self.role != "prefill":
            future = int(min(self.osl_est, max_new))
        return self.kv.pages_for(prompt_len + future + 1)


@dataclasses.dataclass(frozen=True)
class FleetView:
    """Frozen snapshot of the whole fleet at one decision point.

    ``workers`` covers every provisioned replica (warming and draining
    included, flagged on their views); ``pools`` maps each role to the
    indices of its *active* (routable/dispatchable) members, in pool order.
    ``arrivals`` and ``finished`` carry the fleet-level series the scaling
    signals fold (arrival times of everything submitted or still queued
    upstream; finished requests in worker order)."""
    t: float
    workers: Tuple[WorkerView, ...]
    pools: Tuple[Tuple[str, Tuple[int, ...]], ...]
    arrivals: Tuple[float, ...] = ()
    finished: Tuple[Request, ...] = ()
    inflight_migrations: int = 0
    inflight_rebalances: int = 0

    def pool(self, role: str) -> Tuple[WorkerView, ...]:
        for r, idx in self.pools:
            if r == role:
                return tuple(self.workers[i] for i in idx)
        return ()

    def warming_count(self, role: str) -> int:
        return sum(1 for v in self.workers if v.warming and v.role == role)

    def worker(self, name: str) -> Optional[WorkerView]:
        for v in self.workers:
            if v.name == name:
                return v
        return None


@dataclasses.dataclass(frozen=True)
class RebalanceDecision:
    """One decode→decode migration a ``RebalancePolicy`` asks for: move
    running request ``rid`` from worker ``src`` to worker ``dst``.
    ``kv_util`` records the source pressure that triggered it and ``reason``
    a human-readable justification — both land in the ``rebalance`` event's
    payload for the trace."""
    rid: int
    src: str
    dst: str
    kv_util: float = 0.0
    reason: str = ""


@dataclasses.dataclass
class StragglerTracker:
    """Per-worker EWMA of engine step latency, keyed by worker NAME.

    Owned by the runtime (one observation per engine step of a routable
    worker) and published to policies through ``WorkerView.step_ewma`` —
    policies read the view, never this tracker. Name keys survive pool
    mutation; ``forget`` drops a retiree's history so a future replica
    reusing the name cannot inherit a dead worker's straggle."""
    alpha: float = 0.2

    def __post_init__(self):
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {self.alpha}")
        self._ewma: Dict[str, float] = {}

    def note_step(self, name: str, dt: float):
        prev = self._ewma.get(name)
        # first observation seeds the EWMA (no bias toward zero at warmup)
        self._ewma[name] = dt if prev is None \
            else (1 - self.alpha) * prev + self.alpha * dt

    def forget(self, name: str):
        self._ewma.pop(name, None)

    def get(self, name: str) -> Optional[float]:
        return self._ewma.get(name)


# ------------------------------------------------------------- construction
def snapshot(worker, *, straggler: Optional[StragglerTracker] = None,
             warming: bool = False) -> WorkerView:
    """Build a :class:`WorkerView` from a live ``Worker``. The ONLY place
    (besides :class:`KVView.of`) that reads ``engine.alloc``/``engine.sched``
    on behalf of a decision — everything downstream is frozen."""
    e = worker.engine
    sched = e.sched
    alloc = e.alloc
    est = sched.admission.estimator
    osl_est = est._est if est._est is not None else est.prior
    urg = sched.admission.classes.urgency
    grow = worker.role != "prefill"

    def peak_pages(r: Request) -> int:
        # predicted PEAK context of an in-flight request: prompt + max of
        # (predicted OSL, already generated) — identical to the KV-aware
        # admission accounting, so router and admission agree on saturation
        future = max(min(osl_est, r.max_new_tokens), r.generated) if grow \
            else r.generated
        return alloc.pages_for(r.isl + int(future) + 1)

    predicted = sum(peak_pages(r) for r in sched.running)
    predicted += sum(peak_pages(r) for r in sched.waiting)

    by_class: Dict[str, int] = {}
    for r in sched.waiting:
        by_class[r.slo_class] = by_class.get(r.slo_class, 0) + 1

    running_reqs = tuple(
        RequestView(rid=r.rid, slo_class=r.slo_class,
                    urgency=urg(r.slo_class), arrival=r.arrival, isl=r.isl,
                    generated=r.generated, context_len=r.context_len,
                    remaining=r.max_new_tokens - r.generated,
                    prefill_done=r.prefill_done)
        for r in sched.running)

    return WorkerView(
        name=worker.name, role=worker.role,
        prefill_only=sched.cfg.prefill_only, warming=warming,
        draining=worker.draining, now=e.now, has_work=e.has_work,
        sched_has_work=sched.has_work,
        kv=KVView.of(alloc), kv_util=alloc.utilization(),
        predicted_used=predicted, osl_est=osl_est,
        n_running=len(sched.running), n_waiting=len(sched.waiting),
        max_seqs=sched.cfg.max_num_seqs, preemptions=sched.n_preemptions,
        step_ewma=straggler.get(worker.name) if straggler else None,
        waiting_by_class=tuple(sorted(by_class.items())),
        running_reqs=running_reqs)


def fleet_snapshot(rt, t: Optional[float] = None, *,
                   series: bool = True) -> FleetView:
    """Build a :class:`FleetView` from a live ``ClusterRuntime`` — one
    consistent observation of every replica, the role pools, the upstream
    arrival series and the in-flight migration counts. ``series=False``
    skips the fleet-level arrival/finished tuples (they grow with the run;
    the rebalance hot path only reads per-worker state)."""
    views = tuple(snapshot(w, straggler=rt.straggler,
                           warming=w in rt._warming) for w in rt.workers)
    index = {w.name: i for i, w in enumerate(rt.workers)}
    pools = tuple(
        (role, tuple(index[w.name] for w in rt._role_pool(role)))
        for role in ("prefill", "decode", "colocated"))
    arrivals: Tuple[float, ...] = ()
    finished: Tuple[Request, ...] = ()
    if series:
        arrivals = tuple(r.arrival for r in rt.submitted) \
            + tuple(ta for (ta, _, _) in rt._arrivals)
        finished = tuple(r for w in rt.workers
                         for r in w.engine.metrics.finished)
    n_rebal = sum(1 for m in rt._migrating if m.get("rebalance"))
    return FleetView(
        t=rt.makespan if t is None else t, workers=views, pools=pools,
        arrivals=arrivals, finished=finished,
        inflight_migrations=len(rt._migrating),
        inflight_rebalances=n_rebal)


# -------------------------------------------------------------- feasibility
def eligible_indices(views: Sequence[WorkerView], prompt_len: int,
                     max_new: int) -> List[int]:
    """Views that can hold the request at all — policies must not route to
    a worker whose pool is structurally too small (heterogeneous fleets), or
    the engine's fits-alone invariant breaks mid-run. Raises the typed
    :class:`NoFeasibleWorker` when the pool has no candidate."""
    idx = [i for i, v in enumerate(views) if v.fits(prompt_len, max_new)]
    if not idx:
        raise NoFeasibleWorker(
            prompt_len, max_new,
            [(v.name, v.capacity_tokens) for v in views])
    return idx
