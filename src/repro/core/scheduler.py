"""Continuous-batching FCFS scheduler with chunked prefill and preemption
(vLLM-v1 semantics, paper §II-C / §VI-C).

Each engine step builds one iteration batch:
  1. decode slots: one token for every RUNNING request past prefill;
     growing a sequence across a page boundary may require a new page —
     if the pool is exhausted, the *youngest* running request is preempted
     (freed + requeued at the waiting-front for recompute), matching vLLM's
     recompute-mode preemption.
  2. chunked prefill: remaining token budget (max_num_batched_tokens) is
     filled greedily from admitted requests' outstanding prompt chunks.
  3. admission: WAITING requests enter while the AdmissionPolicy allows and
     the concurrency cap (max_num_seqs, possibly autotuned) has room.

Multi-tenant SLO classes (the admission policy's ``ClassPolicy``): a newly
submitted request of a more urgent class is inserted ahead of waiting
lower-urgency requests (never ahead of preempted requests, whose
resume-first position is the forward-progress guarantee), and preemption
victims are drawn from the least urgent running class first — interactive
requests jump batch queues and evict batch KV, batch absorbs the
backpressure.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.admission import AdmissionPolicy
from repro.core.kv_cache import KVView, PagedAllocator
from repro.core.request import Request, State


def victim_order(urgency: int, arrival: float, rid: int) -> Tuple:
    """The victim total order shared by engine preemption and cluster
    rebalancing: least urgent class first, then most recently arrived, ties
    broken by rid (strict total order). ``max`` under this key is the
    canonical victim — evicting (or migrating) it minimises lost work under
    FCFS and never touches the oldest request, preserving the
    forward-progress guarantee."""
    return (-urgency, arrival, rid)


@dataclasses.dataclass
class SchedulerConfig:
    max_num_seqs: int = 256
    max_num_batched_tokens: int = 2048
    chunk_size: int = 512
    prefill_only: bool = False   # disaggregated prefill worker: requests are
                                 # ejected after their first token, so only
                                 # the prompt (not the OSL) must fit the pool


@dataclasses.dataclass
class StepPlan:
    decode: List[Request]
    prefill: List[Tuple[Request, int]]       # (request, chunk_len)
    preempted: List[Request]
    admitted: List[Request]

    @property
    def prefill_tokens(self) -> int:
        return sum(c for _, c in self.prefill)


class Scheduler:
    def __init__(self, cfg: SchedulerConfig, alloc: PagedAllocator,
                 admission: Optional[AdmissionPolicy] = None):
        self.cfg = cfg
        self.alloc = alloc
        self.admission = admission or AdmissionPolicy()
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []
        self.n_preemptions = 0
        # event spine (repro.trace): the owning engine wires its emitter in
        # — admit/resume/preempt are emitted HERE, at the transition itself
        self.emitter = None

    # ------------------------------------------------------------------ api
    def validate(self, req: Request):
        capacity = self.alloc.n_pages * self.alloc.page_size
        peak = req.isl + (1 if self.cfg.prefill_only else req.max_new_tokens)
        if peak + 1 > capacity:
            raise ValueError(
                f"request {req.rid}: context {peak} "
                f"exceeds KV pool capacity {capacity} tokens")

    def submit(self, req: Request):
        self.validate(req)
        self._enqueue(req)

    def _enqueue(self, req: Request):
        """Class-priority insert: jump ahead of strictly-less-urgent waiting
        requests, but never ahead of an equal/higher tier (FCFS within a
        class) and never ahead of a PREEMPTED request — preempted victims
        resume first or the recompute-livelock guard breaks."""
        urg = self.admission.classes.urgency
        pos = len(self.waiting)
        while pos > 0:
            ahead = self.waiting[pos - 1]
            if ahead.state is State.PREEMPTED \
                    or urg(ahead.slo_class) >= urg(req.slo_class):
                break
            pos -= 1
        self.waiting.insert(pos, req)

    def inject_running(self, req: Request) -> bool:
        """Adopt a migrated (prefill-complete) request directly into the
        running set, allocating pages for its existing context. Returns False
        when the concurrency cap or the page pool has no room."""
        if len(self.running) >= self.cfg.max_num_seqs:
            return False
        if not self.alloc.grow(req.rid, req.context_len):
            return False
        req.state = State.RUNNING
        self.running.append(req)
        return True

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def plan_step(self) -> StepPlan:
        preempted: List[Request] = []
        admitted: List[Request] = []

        # 1) decode set — grow pages; preempt youngest on exhaustion.
        # Strict FCFS order (arrival, rid): the oldest request is never a
        # victim, guaranteeing forward progress (no preemption livelock).
        decode: List[Request] = []
        for req in list(sorted(self.running, key=lambda r: (r.arrival, r.rid))):
            if not req.prefill_done:
                continue
            if req not in self.running:      # already preempted this step
                continue
            while not self.alloc.grow(req.rid, req.context_len + 1):
                victim = self._pick_victim(exclude=req)
                if victim is None:
                    # nothing younger to evict: requeue req itself (possible
                    # only transiently — submit() validates it fits alone)
                    self._preempt(req, preempted)
                    break
                self._preempt(victim, preempted)
                if victim in decode:
                    # victim already planned this step: un-plan it, or it
                    # would emit a token whose KV was just freed and then
                    # re-emit the same token after recompute-resume
                    decode.remove(victim)
            if req in self.running:
                decode.append(req)

        # 2) chunked prefill under the token budget
        budget = self.cfg.max_num_batched_tokens - len(decode)
        prefill: List[Tuple[Request, int]] = []
        for req in self.running:
            if req.prefill_done or budget <= 0 or req in preempted:
                continue
            chunk = min(self.cfg.chunk_size,
                        req.prefill_target - req.prompt_pos, budget)
            if chunk <= 0:
                continue
            if not self.alloc.grow(req.rid, req.prompt_pos + chunk):
                continue                      # prefill throttled (no preempt)
            prefill.append((req, chunk))
            budget -= chunk

        # 3) admission — backpressured: a step that preempted admits nothing
        # (otherwise the resumed victim steals back the pages the preemptor
        # just freed and the pair cycles forever — the thrash regime of Obs 1
        # turned into a livelock)
        while (not preempted and self.waiting
               and len(self.running) < self.cfg.max_num_seqs
               and budget > 0):
            cand = self.waiting[0]
            # the admission budget is judged against a frozen KV snapshot —
            # the same decision-plane view (repro.cluster.view) the cluster
            # policies consume — taken at this decision point (per candidate:
            # an admitted candidate's prefill grow must be visible to the
            # next admit, exactly as the live allocator read was)
            if not self.admission.admit(cand, self.running,
                                        KVView.of(self.alloc)):
                break
            chunk = min(self.cfg.chunk_size, cand.prefill_target, budget)
            if chunk <= 0 or not self.alloc.grow(cand.rid, chunk):
                break
            self.waiting.popleft()
            resumed = cand.state is State.PREEMPTED
            cand.state = State.RUNNING
            self.running.append(cand)
            admitted.append(cand)
            prefill.append((cand, chunk))
            budget -= chunk
            if self.emitter is not None:
                if resumed:
                    self.emitter.emit("resume", rid=cand.rid, ref=cand,
                                      resume_extra=cand.resume_extra)
                else:
                    self.emitter.emit("admit", rid=cand.rid, ref=cand)

        return StepPlan(decode=decode, prefill=prefill, preempted=preempted,
                        admitted=admitted)

    def finish(self, req: Request):
        self.running.remove(req)
        self.alloc.free(req.rid)
        req.state = State.FINISHED
        self.admission.estimator.observe(req.generated)

    # ------------------------------------------------------------- internals
    def _pick_victim(self, exclude: Request) -> Optional[Request]:
        """vLLM recompute preemption, class-aware: evict from the least
        urgent running class first, and within a class the most recently
        arrived request (minimises lost work under FCFS). Ties broken by rid
        so the order is a strict total order. Single-class fleets reduce to
        the original youngest-victim rule, keeping its forward-progress
        guarantee (the oldest request is never a victim); across classes the
        guarantee holds per tier — the preemptor always makes progress, so a
        batch victim thrashing under interactive pressure is backpressure,
        not livelock."""
        urg = self.admission.classes.urgency
        cands = [r for r in self.running if r is not exclude]
        if not cands:
            return None
        return max(cands, key=lambda r: victim_order(urg(r.slo_class),
                                                     r.arrival, r.rid))

    def _preempt(self, req: Request, out: List[Request]):
        if self.emitter is not None:
            # capture the victim's cost before the recompute reset wipes it
            self.emitter.emit("preempt", rid=req.rid, ref=req,
                              generated=req.generated,
                              lost_tokens=req.context_len)
        self.alloc.free(req.rid)
        self.running.remove(req)
        # recompute mode: the whole context (prompt + generated-so-far) must
        # be prefill-recomputed on resume
        req.recomputed_tokens += req.context_len
        req.resume_extra = req.generated
        req.prompt_pos = 0
        req.state = State.PREEMPTED
        req.n_preemptions += 1
        self.n_preemptions += 1
        self.waiting.appendleft(req)          # resumes first (FCFS order)
        out.append(req)
