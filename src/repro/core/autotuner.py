"""Online concurrency autotuner (paper Observation 2).

"The optimal operating point is the batch size where TTFT reduction no longer
compensates for TPOT degradation. This motivates online batch-size tuning
using TTFT, TPOT, KV occupancy, and HBM bandwidth as feedback signals."

Hill-climbs max_num_seqs between bounds: backs off multiplicatively on
preemption/KV-pressure, probes upward additively when the queue is deep and
KV has headroom.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class AutotunerConfig:
    enabled: bool = True
    min_seqs: int = 8
    max_seqs: int = 4096
    kv_high: float = 0.92
    kv_low: float = 0.70
    backoff: float = 0.8
    probe: int = 16
    interval: int = 16          # engine steps between adjustments


class ConcurrencyAutotuner:
    def __init__(self, cfg: AutotunerConfig, initial: int):
        self.cfg = cfg
        self.value = initial
        self._steps = 0
        self._preempts_seen = 0

    def update(self, *, kv_util: float, preemptions_total: int,
               waiting: int, running: int) -> int:
        if not self.cfg.enabled:
            return self.value
        self._steps += 1
        if self._steps % self.cfg.interval:
            return self.value
        new_preempts = preemptions_total - self._preempts_seen
        self._preempts_seen = preemptions_total
        if new_preempts > 0 or kv_util > self.cfg.kv_high:
            # capacity trap territory: shed concurrency (Obs 1)
            self.value = max(int(self.value * self.cfg.backoff),
                             self.cfg.min_seqs)
        elif waiting > 0 and kv_util < self.cfg.kv_low:
            # queue-bound with headroom: admit more (TTFT side of Obs 2)
            self.value = min(self.value + self.cfg.probe, self.cfg.max_seqs)
        return self.value
