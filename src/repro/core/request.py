"""Request lifecycle (paper §III-D Request Lifecycle Tracking)."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class State(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]                  # token ids (real exec) — len == ISL
    max_new_tokens: int                # OSL budget
    arrival: float = 0.0
    slo_class: str = ""                # SLO-class tag (multi-tenant tiers);
                                       # "" = the scenario's default class
    # progress
    state: State = State.WAITING
    prompt_pos: int = 0                # chunked-prefill progress
    resume_extra: int = 0              # generated tokens to re-prefill after preemption
    generated: int = 0
    output: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None         # decode slot (real exec)
    # timestamps
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finished: Optional[float] = None
    n_preemptions: int = 0
    recomputed_tokens: int = 0         # prefill work redone after preemption
    # decode-time bookkeeping
    decode_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def isl(self) -> int:
        return len(self.prompt)

    @property
    def prefill_target(self) -> int:
        """Tokens needing prefill: prompt + regenerated prefix after
        recompute-mode preemption."""
        return self.isl + self.resume_extra

    @property
    def context_len(self) -> int:
        """Tokens whose KV is in cache."""
        return self.prompt_pos + self.generated - self.resume_extra

    @property
    def prefill_done(self) -> bool:
        return self.prompt_pos >= self.prefill_target

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new_tokens

    # ---- service metrics -------------------------------------------------
    def ttft(self) -> Optional[float]:
        return None if self.t_first_token is None else \
            self.t_first_token - self.arrival

    def tpot(self) -> Optional[float]:
        if self.t_finished is None or self.t_first_token is None \
                or self.generated <= 1:
            return None
        return (self.t_finished - self.t_first_token) / (self.generated - 1)

    def e2e(self) -> Optional[float]:
        return None if self.t_finished is None else \
            self.t_finished - self.arrival

    def waiting_time(self) -> Optional[float]:
        return None if self.t_admitted is None else \
            self.t_admitted - self.arrival
