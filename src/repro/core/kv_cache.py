"""Paged KV-cache manager (vLLM-style, block size 16 — paper §II-C/§III-A).

Pure host-side page accounting shared by the real-execution and simulated
engines: allocation, per-request page tables, utilisation/fragmentation
telemetry, and a prefix-reuse hook. Device-side paged storage lives in
``repro.models.paged_decode`` + the Pallas paged-attention kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class PagedAllocator:
    n_pages: int
    page_size: int = 16

    def __post_init__(self):
        self._free: List[int] = list(range(self.n_pages))[::-1]
        self._tables: Dict[int, List[int]] = {}
        self._used_tokens: Dict[int, int] = {}
        self.peak_used_pages = 0
        # event spine (repro.trace): the owning engine wires its emitter in
        # so every page movement is on the stream (kv_alloc / kv_free)
        self.emitter = None

    # ---- queries ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def utilization(self) -> float:
        """Fraction of page pool allocated (the paper's 'Aggregated KV
        Cache Util.')."""
        return self.used_pages / self.n_pages if self.n_pages else 0.0

    def internal_fragmentation(self) -> float:
        """Allocated-but-unused token slots / allocated slots ('stranded
        capacity' inside pages)."""
        cap = self.used_pages * self.page_size
        if cap == 0:
            return 0.0
        used = sum(self._used_tokens.values())
        return 1.0 - used / cap

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def table(self, rid: int) -> List[int]:
        return self._tables.get(rid, [])

    def tokens_of(self, rid: int) -> int:
        return self._used_tokens.get(rid, 0)

    # ---- mutation ---------------------------------------------------------
    def grow(self, rid: int, new_total_tokens: int) -> bool:
        """Ensure rid has pages for new_total_tokens; False if pool exhausted
        (caller must preempt). All-or-nothing: a failed grow leaves no
        table entry behind for a rid that had none."""
        have = self._tables.get(rid, [])
        need = self.pages_for(new_total_tokens) - len(have)
        if need > len(self._free):
            return False
        for _ in range(max(need, 0)):
            have.append(self._free.pop())
        self._tables[rid] = have
        self._used_tokens[rid] = new_total_tokens
        self.peak_used_pages = max(self.peak_used_pages, self.used_pages)
        if need > 0 and self.emitter is not None:
            self.emitter.emit("kv_alloc", rid=rid, pages=need,
                              held=len(have), tokens=new_total_tokens)
        return True

    def free(self, rid: int) -> int:
        pages = self._tables.pop(rid, [])
        self._free.extend(pages)
        self._used_tokens.pop(rid, None)
        if pages and self.emitter is not None:
            self.emitter.emit("kv_free", rid=rid, pages=len(pages))
        return len(pages)

    def reset(self):
        self.__post_init__()


@dataclasses.dataclass(frozen=True)
class KVView:
    """Frozen, read-only snapshot of a :class:`PagedAllocator` — the KV leg
    of the decision plane (see ``repro.cluster.view``).

    Carries exactly what capacity/headroom decisions need (pool size, page
    geometry, current occupancy) and the pure ``pages_for`` arithmetic, so
    admission budgets, routing feasibility and rebalancing all compute
    headroom from one snapshot instead of scraping allocator internals.
    Duck-type-compatible with the allocator for ``AdmissionPolicy.admit``
    (which reads only ``n_pages`` / ``free_pages`` / ``pages_for``)."""
    n_pages: int
    page_size: int
    used_pages: int
    free_pages: int

    @classmethod
    def of(cls, alloc: "PagedAllocator") -> "KVView":
        return cls(n_pages=alloc.n_pages, page_size=alloc.page_size,
                   used_pages=alloc.used_pages, free_pages=alloc.free_pages)

    @property
    def capacity_tokens(self) -> int:
        """Structural pool capacity: every page filled to the brim."""
        return self.n_pages * self.page_size

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def utilization(self) -> float:
        return self.used_pages / self.n_pages if self.n_pages else 0.0


def kv_pages_needed(cfg, tokens: int, page_size: int = 16) -> int:
    """Pages needed for `tokens` of context (token-granular; all layers share
    a page table as in vLLM's per-layer parallel allocation)."""
    return -(-tokens // page_size)
