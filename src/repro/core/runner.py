"""Model runners behind the engine.

``SimRunner``   — advances a virtual clock with the analytical perf model
                  (frontier-scale studies; H200 constants reproduce the
                  paper's figures, v5e constants drive TPU planning).
``JaxRunner``   — real execution of a (small) model on this host: slot-based
                  decode cache, whole-prompt prefill scattered into the slot,
                  batched masked decode. The paged-accounting layer in the
                  scheduler is identical in both modes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import perf_model as pm
from repro.core.request import Request


class SimRunner:
    """Virtual-clock runner: returns iteration latencies, emits dummy tokens."""

    def __init__(self, cfg: ModelConfig, plan: pm.ParallelismPlan,
                 hw: pm.Hardware, dtype_bytes: int = 2):
        self.cfg = cfg
        self.plan = plan
        self.hw = hw
        self.dtype_bytes = dtype_bytes

    def iteration_time(self, prefill_tokens: int, decode_reqs: List[Request]
                       ) -> Tuple[float, Dict[str, float]]:
        cfg, plan, hw = self.cfg, self.plan, self.hw
        parts = {"compute": 0.0, "memory": 0.0, "comm": 0.0}
        t = 0.0
        if prefill_tokens:
            p = pm.prefill_step_time(cfg, prefill_tokens, plan, hw,
                                     self.dtype_bytes)
            t += p["total"]
            for k in parts:
                parts[k] += p[k]
        if decode_reqs:
            mean_ctx = float(np.mean([r.context_len for r in decode_reqs]))
            d = pm.decode_step_time(cfg, len(decode_reqs), mean_ctx, plan, hw,
                                    self.dtype_bytes)
            bubble = pm.pp_bubble_factor(cfg, plan, hw, len(decode_reqs),
                                         mean_ctx, self.dtype_bytes)
            t += d["total"] * bubble \
                + pm.pp_transport_time(cfg, len(decode_reqs), plan, hw,
                                       self.dtype_bytes)
            for k in parts:
                parts[k] += d[k]
        return t, parts

    def prefill(self, req: Request, chunk: int) -> int:
        return 0   # dummy token id

    def decode(self, reqs: List[Request]) -> List[int]:
        return [0] * len(reqs)

    def release(self, req: Request):
        pass

    def hbm_busy_fraction(self, parts: Dict[str, float], t: float) -> float:
        return min(parts["memory"] / t, 1.0) if t > 0 else 0.0


class JaxRunner:
    """Real execution with slot-based decode state (CPU-scale models)."""

    def __init__(self, cfg: ModelConfig, params, ctx, max_slots: int,
                 max_len: int, cache_dtype=None):
        import jax
        import jax.numpy as jnp
        from repro.models import transformer as T
        self.cfg, self.params, self.ctx = cfg, params, ctx
        self.max_slots, self.max_len = max_slots, max_len
        self._jnp = jnp
        self._T = T
        dt = cache_dtype or jnp.float32
        self.state = T.init_decode_state(cfg, ctx, max_slots, max_len, dt)
        self._free_slots = list(range(max_slots))[::-1]
        self._slot_of: Dict[int, int] = {}
        self._prefill_fn = jax.jit(
            lambda p, tok: T.prefill(p, tok, cfg, ctx, max_len=max_len,
                                     cache_dtype=dt))
        self._decode_fn = jax.jit(
            lambda p, st, tok, active: self._masked_decode(p, st, tok, active))

    def _masked_decode(self, params, state, tokens, active):
        logits, new_state = self._T.decode_step(params, state, tokens,
                                                self.cfg, self.ctx)
        # keep inactive slots untouched
        merged = self._tree_select(new_state, state, active)
        return logits, merged

    def _bmask(self, active, arr):
        jnp = self._jnp
        # the slot axis is the unique axis whose size == max_slots (engine
        # tests must pick max_slots distinct from structural dims)
        matches = [ax for ax in range(arr.ndim)
                   if arr.shape[ax] == self.max_slots]
        if not matches:
            return jnp.ones((), bool)
        assert len(matches) == 1, \
            f"ambiguous slot axis for shape {arr.shape}; pick another max_slots"
        shape = [1] * arr.ndim
        shape[matches[0]] = self.max_slots
        return active.reshape(shape)

    def _tree_select(self, new, old, active):
        import jax
        return jax.tree_util.tree_map(
            lambda n, o: self._jnp.where(self._bmask(active, n), n, o)
            if n.ndim else n, new, old)

    # ------------------------------------------------------------------ api
    def prefill(self, req: Request, chunk: int) -> int:
        """Whole-prompt prefill into the request's slot; returns first token."""
        import jax
        jnp = self._jnp
        if req.rid not in self._slot_of:
            self._slot_of[req.rid] = self._free_slots.pop()
        slot = self._slot_of[req.rid]
        toks = req.prompt + req.output[:req.resume_extra]
        tokens = jnp.asarray([toks], jnp.int32)
        last, fresh = self._prefill_fn(self.params, tokens)
        self.state = self._scatter_slot(self.state, fresh, slot)
        return int(jnp.argmax(last[0]))

    def _scatter_slot(self, state, fresh, slot):
        import jax

        def put(dst, src):
            if dst.ndim == 0:
                return dst
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.max_slots and src.shape[ax] == 1:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slice(slot, slot + 1)
                    if ax + 1 < dst.ndim and dst.shape[ax + 1] != src.shape[ax + 1]:
                        # seq axis shorter in fresh state: write the prefix
                        idx[ax + 1] = slice(0, src.shape[ax + 1])
                    return dst.at[tuple(idx)].set(src)
            return dst
        return jax.tree_util.tree_map(put, state, fresh)

    def decode(self, reqs: List[Request]) -> List[int]:
        jnp = self._jnp
        slots = [self._slot_of[r.rid] for r in reqs]
        tokens = np.zeros((self.max_slots, 1), np.int32)
        active = np.zeros((self.max_slots,), bool)
        for r, s in zip(reqs, slots):
            last = r.output[-1] if r.output else (r.prompt[-1] if r.prompt else 0)
            tokens[s, 0] = last
            active[s] = True
        logits, self.state = self._decode_fn(
            self.params, self.state, jnp.asarray(tokens), jnp.asarray(active))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        return [int(nxt[s]) for s in slots]

    def release(self, req: Request):
        slot = self._slot_of.pop(req.rid, None)
        if slot is not None:
            self._free_slots.append(slot)

    def iteration_time(self, prefill_tokens, decode_reqs):
        return None, {}   # real mode: engine uses wall-clock
