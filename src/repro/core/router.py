"""Memory-aware DP routing + straggler mitigation (paper Obs 3/4).

"DP should be combined with admission control or memory-aware routing to
prevent each replica from independently entering a preemption-heavy regime"
and "tail latency is dominated by the replica that reaches KV saturation
first" — the router scores replicas by predicted KV headroom (not just queue
depth) and penalises stragglers via an EWMA of per-step latency.

The policies themselves live in ``repro.cluster.policies`` as pluggable
``RoutingPolicy`` objects shared with the cluster runtime; ``DPRouter`` is
the single-router colocated front-end that co-simulates its replicas on a
shared virtual clock (the pre-cluster API, kept for the DP benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.engine import InferenceEngine
from repro.core.request import Request


@dataclasses.dataclass
class RouterConfig:
    policy: str = "memory_aware"   # round_robin | jsq | memory_aware
    straggler_penalty: float = 2.0
    ewma_alpha: float = 0.2


class DPRouter:
    def __init__(self, replicas: List[InferenceEngine],
                 cfg: Optional[RouterConfig] = None):
        # deferred upward import: policies live with the cluster layer (they
        # score WorkerViews); core stays importable standalone and the cycle
        # (cluster.worker -> core.engine) is avoided. Keep cluster imports
        # out of core module scope.
        from repro.cluster.policies import RoutingPolicy, make_policy
        from repro.cluster.view import StragglerTracker, snapshot
        from repro.cluster.worker import Worker
        self.replicas = replicas
        self.cfg = cfg or RouterConfig()
        self.workers = [Worker(engine=e, role="colocated", name=f"dp{i}")
                        for i, e in enumerate(replicas)]
        # per-replica step-latency EWMA, router-owned: policies read it from
        # the WorkerView snapshots built per pick (the decision plane)
        self.straggler = StragglerTracker(alpha=self.cfg.ewma_alpha)
        self._snapshot = snapshot
        if self.cfg.policy == "memory_aware":
            self.policy: RoutingPolicy = make_policy(
                "memory_aware", straggler_penalty=self.cfg.straggler_penalty)
        else:
            self.policy = make_policy(self.cfg.policy)

    def note_step(self, i: int, dt: float):
        self.straggler.note_step(self.workers[i].name, dt)

    def pick(self, prompt_len: int, max_new: int) -> int:
        views = [self._snapshot(w, straggler=self.straggler)
                 for w in self.workers]
        return self.policy.pick(views, prompt_len, max_new)

    def submit(self, prompt, max_new: int, arrival: float = None) -> Request:
        plen = prompt if isinstance(prompt, int) else len(prompt)
        i = self.pick(plen, max_new)
        return self.replicas[i].submit(prompt, max_new, arrival)

    def run_all(self, max_steps: int = 10 ** 7):
        """Co-simulate replicas on a shared virtual clock."""
        active = True
        steps = 0
        while active and steps < max_steps:
            active = False
            for i, e in enumerate(self.replicas):
                t0 = e.now
                if e.step():
                    active = True
                    self.note_step(i, e.now - t0)
            steps += 1
        return [e.metrics for e in self.replicas]
