"""Memory-aware DP routing + straggler mitigation (paper Obs 3/4).

"DP should be combined with admission control or memory-aware routing to
prevent each replica from independently entering a preemption-heavy regime"
and "tail latency is dominated by the replica that reaches KV saturation
first" — the router scores replicas by predicted KV headroom (not just queue
depth) and penalises stragglers via an EWMA of per-step latency.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.engine import InferenceEngine
from repro.core.request import Request


@dataclasses.dataclass
class RouterConfig:
    policy: str = "memory_aware"   # round_robin | jsq | memory_aware
    straggler_penalty: float = 2.0
    ewma_alpha: float = 0.2


class DPRouter:
    def __init__(self, replicas: List[InferenceEngine],
                 cfg: Optional[RouterConfig] = None):
        self.replicas = replicas
        self.cfg = cfg or RouterConfig()
        self._rr = 0
        self._lat_ewma = [0.0] * len(replicas)
        self._last_t = [0.0] * len(replicas)

    def note_step(self, i: int, dt: float):
        a = self.cfg.ewma_alpha
        self._lat_ewma[i] = (1 - a) * self._lat_ewma[i] + a * dt

    def pick(self, prompt_len: int, max_new: int) -> int:
        c = self.cfg
        if c.policy == "round_robin":
            self._rr = (self._rr + 1) % len(self.replicas)
            return self._rr
        if c.policy == "jsq":
            return min(range(len(self.replicas)),
                       key=lambda i: len(self.replicas[i].sched.waiting)
                       + len(self.replicas[i].sched.running))
        # memory_aware: predicted pages after this request, plus straggler term
        def score(i):
            e = self.replicas[i]
            est = e.sched.admission.estimator.predict
            pred = sum(e.alloc.pages_for(
                r.isl + int(est(r))) for r in e.sched.running)
            pred += sum(e.alloc.pages_for(r.isl + int(est(r)))
                        for r in e.sched.waiting)
            pred += e.alloc.pages_for(prompt_len + max_new)
            headroom = e.alloc.n_pages - pred
            mean_lat = (sum(self._lat_ewma) / len(self._lat_ewma)) or 1e-9
            straggle = self._lat_ewma[i] / mean_lat
            return (-headroom, straggle * c.straggler_penalty)
        return min(range(len(self.replicas)), key=score)

    def submit(self, prompt, max_new: int, arrival: float = None) -> Request:
        plen = prompt if isinstance(prompt, int) else len(prompt)
        i = self.pick(plen, max_new)
        return self.replicas[i].submit(prompt, max_new, arrival)

    def run_all(self, max_steps: int = 10 ** 7):
        """Co-simulate replicas on a shared virtual clock."""
        active = True
        steps = 0
        while active and steps < max_steps:
            active = False
            for i, e in enumerate(self.replicas):
                t0 = e.now
                if e.step():
                    active = True
                    self.note_step(i, e.now - t0)
            steps += 1
        return [e.metrics for e in self.replicas]
