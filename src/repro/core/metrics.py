"""Engine telemetry — the paper's §III-D metric set: TTFT, TPOT, generation
throughput, E2E, request lifecycle decomposition, KV saturation, preemptions,
plus modeled HBM-bandwidth utilisation in simulated mode, and SLO-goodput
accounting (tokens/s delivered within latency targets) for the cluster layer."""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List, Optional

from repro.core.request import Request


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request latency targets. A request attains the SLO iff its TTFT
    and its mean TPOT both meet their targets (the serving-level contract the
    paper's goodput discussions assume). A target of None is unconstrained."""
    ttft_s: Optional[float] = None
    tpot_s: Optional[float] = None

    def attained(self, req: Request) -> bool:
        if req.t_finished is None:
            return False
        if self.ttft_s is not None:
            ttft = req.ttft()
            if ttft is None or ttft > self.ttft_s:
                return False
        if self.tpot_s is not None:
            tpot = req.tpot()
            if tpot is not None and tpot > self.tpot_s:
                return False
        return True


def slo_attainment(reqs: List[Request], slo: SLO) -> float:
    """Fraction of finished requests meeting the SLO."""
    done = [r for r in reqs if r.t_finished is not None]
    if not done:
        return 0.0
    return sum(slo.attained(r) for r in done) / len(done)


def goodput_tok_s(reqs: List[Request], slo: SLO,
                  duration_s: Optional[float] = None) -> float:
    """Fleet goodput: generated tokens of SLO-attaining requests per second
    (tokens served outside the SLO are throughput, not goodput)."""
    done = [r for r in reqs if r.t_finished is not None]
    if not done:
        return 0.0
    good = sum(r.generated for r in done if slo.attained(r))
    if duration_s is None:
        t0 = min(r.arrival for r in done)
        t1 = max(r.t_finished for r in done)
        duration_s = max(t1 - t0, 1e-9)
    return good / duration_s


@dataclasses.dataclass
class TimelinePoint:
    t: float
    running: int
    waiting: int
    kv_util: float
    kv_frag: float
    gen_tokens: int          # cumulative
    prefill_tokens: int      # cumulative
    preemptions: int         # cumulative
    hbm_busy: float = 0.0    # modeled fraction (sim mode)


class MetricsLog:
    def __init__(self):
        self.timeline: List[TimelinePoint] = []
        self.finished: List[Request] = []
        self.preemption_events: List[float] = []
        self.throttle_events: List[float] = []

    def snapshot(self, **kw):
        self.timeline.append(TimelinePoint(**kw))

    def finish(self, req: Request):
        self.finished.append(req)

    # ---- summaries ---------------------------------------------------------
    @staticmethod
    def _stats(vals: List[float]) -> Dict[str, float]:
        vals = [v for v in vals if v is not None]
        if not vals:
            return {"mean": 0.0, "p50": 0.0, "p95": 0.0, "max": 0.0}
        s = sorted(vals)
        return {
            "mean": statistics.fmean(s),
            "p50": s[len(s) // 2],
            "p95": s[min(int(len(s) * 0.95), len(s) - 1)],
            "max": s[-1],
        }

    def summary(self, horizon: Optional[float] = None) -> Dict:
        reqs = self.finished
        gen_tokens = sum(r.generated for r in reqs)
        t_end = max((r.t_finished or 0.0) for r in reqs) if reqs else 0.0
        t_start = min(r.arrival for r in reqs) if reqs else 0.0
        dur = horizon or max(t_end - t_start, 1e-9)
        out = {
            "n_finished": len(reqs),
            "gen_tokens": gen_tokens,
            "gen_throughput_tok_s": gen_tokens / dur,
            "duration_s": dur,
            "ttft_s": self._stats([r.ttft() for r in reqs]),
            "tpot_s": self._stats([r.tpot() for r in reqs]),
            "e2e_s": self._stats([r.e2e() for r in reqs]),
            "waiting_s": self._stats([r.waiting_time() for r in reqs]),
            "preemptions": sum(r.n_preemptions for r in reqs),
            "recomputed_tokens": sum(r.recomputed_tokens for r in reqs),
            "peak_kv_util": max((p.kv_util for p in self.timeline), default=0.0),
            "mean_kv_util": statistics.fmean(
                [p.kv_util for p in self.timeline]) if self.timeline else 0.0,
        }
        return out

    def slo_summary(self, slo: SLO, duration_s: Optional[float] = None
                    ) -> Dict[str, float]:
        return {
            "slo_attainment": slo_attainment(self.finished, slo),
            "goodput_tok_s": goodput_tok_s(self.finished, slo, duration_s),
        }
