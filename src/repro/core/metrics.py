"""Engine telemetry — the paper's §III-D metric set: TTFT, TPOT, generation
throughput, E2E, request lifecycle decomposition, KV saturation, preemptions,
plus modeled HBM-bandwidth utilisation in simulated mode, and SLO-goodput
accounting (tokens/s delivered within latency targets) for the cluster layer.

Goodput accounting ("tokens served outside the SLO are throughput, not
goodput") is honest about its denominators:

  * duration comes from an explicit makespan when the caller has one (the
    cluster runtime's fleet clock at drain) — a finished-only window ignores
    the tail still being served and inflates goodput;
  * with a ``horizon``, submitted-but-unfinished requests count as SLO
    misses — the worst violators are exactly the ones still in flight.

``slo_summary`` is class-conditional: requests carry an ``slo_class`` tag and
each class is judged against its own ``SLO`` (multi-tenant interactive/batch
tiers); class goodputs sum to fleet goodput by construction (shared duration,
disjoint request buckets).
"""
from __future__ import annotations

import dataclasses
import math
import statistics
from typing import Dict, List, Mapping, Optional, Union

from repro.core.request import Request


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request latency targets. A request attains the SLO iff its TTFT
    and its mean TPOT both meet their targets (the serving-level contract the
    paper's goodput discussions assume). A target of None is unconstrained.

    An *undefined measurement* (None) vacuously satisfies its target — the
    rule is symmetric for TTFT and TPOT. For finished requests TTFT is always
    defined; TPOT is undefined only for single-token outputs, which cannot
    violate an inter-token contract. Unfinished requests never attain here;
    counting them as misses against a horizon is the caller's job
    (``slo_attainment(horizon=...)``)."""
    ttft_s: Optional[float] = None
    tpot_s: Optional[float] = None

    def attained(self, req: Request) -> bool:
        if req.t_finished is None:
            return False
        if self.ttft_s is not None:
            ttft = req.ttft()
            if ttft is not None and ttft > self.ttft_s:
                return False
        if self.tpot_s is not None:
            tpot = req.tpot()
            if tpot is not None and tpot > self.tpot_s:
                return False
        return True


def attained_by(req: Request, slo: SLO,
                horizon: Optional[float] = None) -> bool:
    """``slo.attained`` windowed: with a horizon, only requests *finished by
    the horizon* can attain — one still in flight (or finishing later) is a
    miss within that window."""
    if horizon is not None and (req.t_finished is None
                                or req.t_finished > horizon):
        return False
    return slo.attained(req)


def finished_window_s(reqs: List[Request]) -> float:
    """First arrival -> last finish over finished requests: the legacy
    closed-loop duration fallback when no makespan is known. The ONE place
    this window is defined — it understates the serving window whenever
    work is still in flight, so callers with a makespan must pass it."""
    done = [r for r in reqs if r.t_finished is not None]
    if not done:
        return 1e-9
    return max(max(r.t_finished for r in done)
               - min(r.arrival for r in done), 1e-9)


def slo_attainment(reqs: List[Request], slo: SLO,
                   horizon: Optional[float] = None) -> float:
    """Fraction of requests meeting the SLO.

    Without a horizon: over finished requests only (the legacy closed-loop
    view). With a horizon: over every submitted request — a request still in
    flight at the horizon (or finishing after it) is an SLO miss, not a free
    pass (the worst violators are the ones that never finished)."""
    if horizon is None:
        pool = [r for r in reqs if r.t_finished is not None]
    else:
        pool = list(reqs)
    if not pool:
        return 0.0
    return sum(attained_by(r, slo, horizon) for r in pool) / len(pool)


def goodput_tok_s(reqs: List[Request], slo: SLO,
                  duration_s: Optional[float] = None,
                  horizon: Optional[float] = None) -> float:
    """Fleet goodput: generated tokens of SLO-attaining requests per second
    (tokens served outside the SLO are throughput, not goodput). Pass the
    run's actual makespan as ``duration_s`` — deriving the window from
    finished requests only shrinks the denominator while the tail is still
    being served, inflating goodput. With a ``horizon``, only requests
    finished by it contribute good tokens (same windowing as
    ``slo_attainment``)."""
    good = sum(r.generated for r in reqs if attained_by(r, slo, horizon))
    if duration_s is None:
        if not any(r.t_finished is not None for r in reqs):
            return 0.0
        duration_s = finished_window_s(reqs)
    return good / max(duration_s, 1e-9)


def latency_stats(vals: List[Optional[float]]) -> Dict[str, float]:
    """Summary stats over the defined (non-None) values: mean, true median
    (even-length lists average the two middle values), nearest-rank p95
    (the ceil(0.95 n)-th order statistic — NOT ``int(0.95 n)``, which lands
    on the max for n <= 20), and max. The one shared percentile helper —
    engine and cluster summaries must agree on what "p95" means."""
    s = sorted(v for v in vals if v is not None)
    if not s:
        return {"mean": 0.0, "p50": 0.0, "p95": 0.0, "max": 0.0}
    return {
        "mean": statistics.fmean(s),
        "p50": statistics.median(s),
        "p95": s[max(math.ceil(0.95 * len(s)) - 1, 0)],
        "max": s[-1],
    }


# ------------------------------------------------------- class-conditional SLO
SLOMap = Mapping[str, SLO]


def _as_slo_map(slo: Union[SLO, SLOMap]) -> Dict[str, SLO]:
    return dict(slo) if isinstance(slo, Mapping) else {"default": slo}


def class_slo_summary(reqs: List[Request], slos: Union[SLO, SLOMap],
                      duration_s: float,
                      horizon: Optional[float] = None) -> Dict:
    """Attainment + goodput, overall and per SLO class.

    ``slos`` maps class name -> SLO; a bare SLO means one class. Requests are
    bucketed by their ``slo_class`` tag (unknown/untagged requests fall into
    the first class, the default). Every request is judged against its own
    class's targets; the overall attainment is over all requests and the
    per-class goodputs sum to the overall goodput (same duration, disjoint
    buckets)."""
    table = _as_slo_map(slos)
    default = next(iter(table))
    buckets: Dict[str, List[Request]] = {name: [] for name in table}
    for r in reqs:
        buckets[r.slo_class if r.slo_class in table else default].append(r)

    classes = {}
    n_total = att_total = 0
    good_total = 0.0
    for name, slo in table.items():
        rs = buckets[name]
        pool = rs if horizon is not None \
            else [r for r in rs if r.t_finished is not None]
        att = sum(attained_by(r, slo, horizon) for r in pool)
        good = goodput_tok_s(rs, slo, duration_s, horizon=horizon)
        classes[name] = {
            "n": len(rs),
            "n_finished": sum(r.t_finished is not None for r in rs),
            "slo_attainment": att / len(pool) if pool else 0.0,
            "goodput_tok_s": good,
        }
        n_total += len(pool)
        att_total += att
        good_total += good
    return {
        "slo_attainment": att_total / n_total if n_total else 0.0,
        "goodput_tok_s": good_total,
        "classes": classes,
    }


@dataclasses.dataclass
class TimelinePoint:
    t: float
    running: int
    waiting: int
    kv_util: float
    kv_frag: float
    gen_tokens: int          # cumulative
    prefill_tokens: int      # cumulative
    preemptions: int         # cumulative
    hbm_busy: float = 0.0    # modeled fraction (sim mode)
    kv_pages_used: int = 0   # absolute page counts (repro.obs windows
    kv_pages_free: int = 0   # consume the stream without engine access)
    max_seqs: int = 0        # live concurrency cap (moves under autotune)


class MetricsLog:
    """Per-engine accounting, derived purely from the event spine.

    The engine subscribes this log to its ``repro.trace`` event stream at
    construction; every list here is a fold over that stream (``arrival`` /
    ``inject`` grow the submitted log, ``eject`` shrinks it — per-engine SLO
    accounting covers requests the engine is responsible for finishing —
    ``finish`` appends to ``finished``, ``step`` appends a
    ``TimelinePoint``). Nothing else may mutate this state (lint REP009)."""

    def __init__(self):
        self.timeline: List[TimelinePoint] = []
        self.submitted: List[Request] = []
        self.finished: List[Request] = []
        self.preemption_events: List[float] = []

    # ---- the one mutation path: the event stream -------------------------
    def on_event(self, ev):
        kind = ev.kind
        if kind == "arrival" or kind == "inject":
            # unfinished requests must be visible to the horizon-based SLO
            # accounting (they are misses, not omissions)
            self.submitted.append(ev.ref)
        elif kind == "eject":
            # the adopter records it on inject; fleet-level accounting
            # lives in ClusterMetrics
            if ev.ref in self.submitted:
                self.submitted.remove(ev.ref)
        elif kind == "finish":
            self.finished.append(ev.ref)
        elif kind == "preempt":
            self.preemption_events.append(ev.t)
        elif kind == "step":
            self.timeline.append(TimelinePoint(t=ev.t, **ev.payload))

    # ---- summaries ---------------------------------------------------------
    def summary(self, horizon: Optional[float] = None) -> Dict:
        reqs = self.finished
        gen_tokens = sum(r.generated for r in reqs)
        t_end = max((r.t_finished or 0.0) for r in reqs) if reqs else 0.0
        t_start = min(r.arrival for r in reqs) if reqs else 0.0
        dur = horizon or max(t_end - t_start, 1e-9)
        out = {
            "n_finished": len(reqs),
            "gen_tokens": gen_tokens,
            "gen_throughput_tok_s": gen_tokens / dur,
            "duration_s": dur,
            "ttft_s": latency_stats([r.ttft() for r in reqs]),
            "tpot_s": latency_stats([r.tpot() for r in reqs]),
            "e2e_s": latency_stats([r.e2e() for r in reqs]),
            "waiting_s": latency_stats([r.waiting_time() for r in reqs]),
            "preemptions": sum(r.n_preemptions for r in reqs),
            "recomputed_tokens": sum(r.recomputed_tokens for r in reqs),
            "peak_kv_util": max((p.kv_util for p in self.timeline), default=0.0),
            "mean_kv_util": statistics.fmean(
                [p.kv_util for p in self.timeline]) if self.timeline else 0.0,
        }
        return out

    def slo_summary(self, slo: Union[SLO, SLOMap],
                    duration_s: Optional[float] = None,
                    horizon: Optional[float] = None) -> Dict:
        """SLO attainment + goodput, per class and overall. With a horizon,
        submitted-but-unfinished requests count as misses and the horizon is
        the default duration."""
        reqs = self.submitted if (horizon is not None and self.submitted) \
            else self.finished
        if duration_s is None:
            if horizon is not None:
                t0 = min((r.arrival for r in reqs), default=0.0)
                duration_s = max(horizon - t0, 1e-9)
            else:
                duration_s = finished_window_s(reqs)
        return class_slo_summary(reqs, slo, duration_s, horizon=horizon)
