"""Parallelism planner — the paper's operational decision framework (§IV-§VI)
as an analytical model: given (model, hardware, device budget, workload),
rank DP/TP/PP/EP plans by estimated batch completion time, with feasibility
from weight/KV capacity.

The regression targets are the paper's own measurements on 8xH200
(tests/test_planner.py):
  * 8B/14B  -> pure DP wins (Obs 5)
  * 32B     -> DP4xTP2 beats both DP8 and TP8 (the 'right-sized TP' point)
  * 405B    -> TP8 wins; PP8 catastrophic (KV-starved bubbles, §V-C)
  * R1-671B -> PP4xTP2 beats TP8 (sync-latency-bound sparse model, Obs 6)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import perf_model as pm


@dataclasses.dataclass(frozen=True)
class Workload:
    n_requests: int = 2000
    mean_isl: float = 105.0
    mean_osl: float = 6800.0
    max_num_seqs: int = 256       # per-replica engine cap (vLLM default)


@dataclasses.dataclass
class PlanEstimate:
    plan: pm.ParallelismPlan
    feasible: bool
    reason: str = ""
    completion_s: float = float("inf")
    decode_tput_tok_s: float = 0.0
    concurrency: int = 0
    kv_capacity_tokens: int = 0
    step_parts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def label(self) -> str:
        return self.plan.label()


def candidate_plans(n_devices: int) -> List[pm.ParallelismPlan]:
    out = []
    for tp in (1, 2, 4, 8, 16):
        for pp in (1, 2, 4, 8, 16):
            if tp * pp > n_devices or n_devices % (tp * pp):
                continue
            dp = n_devices // (tp * pp)
            out.append(pm.ParallelismPlan(dp=dp, tp=tp, pp=pp, ep=tp))
    return out


def estimate(cfg: ModelConfig, plan: pm.ParallelismPlan, hw: pm.Hardware,
             wl: Workload, dtype_bytes: int = 2,
             cache_dtype_bytes: int = 2,
             kv_cap_tokens: Optional[int] = None) -> PlanEstimate:
    """Rank one plan. ``kv_cap_tokens`` pins the per-replica KV pool to an
    externally chosen size (a Scenario's explicit ``n_pages``) instead of the
    hardware-derived capacity — the engine and planner fidelities then reason
    about the same pool."""
    shard = plan.tp * plan.pp
    w_per_dev = pm.weight_bytes(cfg, dtype_bytes) / shard
    if w_per_dev > hw.hbm_cap * 0.95:
        return PlanEstimate(plan, False,
                            reason=f"weights {w_per_dev/1e9:.0f}GB/dev > HBM")
    cap = kv_cap_tokens if kv_cap_tokens is not None \
        else pm.kv_capacity_tokens(cfg, plan, hw, dtype_bytes,
                                   cache_dtype_bytes=cache_dtype_bytes)
    mean_ctx = wl.mean_isl + wl.mean_osl / 2
    conc = int(min(cap / max(mean_ctx, 1), wl.max_num_seqs))
    if conc < 1:
        return PlanEstimate(plan, False, reason="no KV room for one request",
                            kv_capacity_tokens=cap)

    d = pm.decode_step_time(cfg, conc, mean_ctx, plan, hw, dtype_bytes,
                            cache_dtype_bytes)
    step = d["total"] + pm.pp_transport_time(cfg, conc, plan, hw, dtype_bytes)
    tput_replica = conc / step                       # decode tokens/s/replica
    tput = tput_replica * plan.dp
    decode_time = wl.n_requests * wl.mean_osl / tput

    p = pm.prefill_step_time(cfg, 2048, plan, hw, dtype_bytes)
    prefill_tput = 2048 / p["total"] * plan.dp
    prefill_time = wl.n_requests * wl.mean_isl / prefill_tput

    # capacity-pressure penalty: when per-replica concurrency is far below
    # the workload's appetite, the scheduler thrashes (admission/preemption,
    # Obs 1) — recompute overhead calibrated on the paper's 32B DP8 point
    pressure = min(wl.max_num_seqs / max(conc, 1), 50.0)
    penalty = 1.0 + 0.08 * max(pressure - 1.0, 0.0)

    total = (decode_time + prefill_time) * penalty
    return PlanEstimate(plan, True, completion_s=total,
                        decode_tput_tok_s=tput, concurrency=conc,
                        kv_capacity_tokens=cap, step_parts=d)


def plan(cfg: ModelConfig, hw: pm.Hardware, n_devices: int,
         wl: Optional[Workload] = None, dtype_bytes: int = 2,
         cache_dtype_bytes: int = 2,
         kv_cap_tokens: Optional[int] = None) -> List[PlanEstimate]:
    wl = wl or Workload()
    ests = [estimate(cfg, p, hw, wl, dtype_bytes, cache_dtype_bytes,
                     kv_cap_tokens)
            for p in candidate_plans(n_devices)]
    return sorted(ests, key=lambda e: (not e.feasible, e.completion_s))


def best(cfg: ModelConfig, hw: pm.Hardware, n_devices: int,
         wl: Optional[Workload] = None, dtype_bytes: int = 2) -> PlanEstimate:
    return plan(cfg, hw, n_devices, wl, dtype_bytes)[0]
