"""Analytical step-latency model for (model x phase x parallelism x hardware).

This is the quantitative core of the paper's decision framework (§IV-§VI):
prefill is compute-bound, decode is HBM-bandwidth + capacity bound, TP pays
per-layer all-reduce bandwidth *and* latency (the alpha term that throttles
sparse models, Obs 6), PP pays bubbles that KV capacity may prevent filling
(the 405B pathology), and DP pays nothing but replicates weights (the
capacity trap, Obs 3/4).

The same model drives the discrete-event simulator (benchmarks, paper-figure
reproduction on H200 constants) and the deployment planner (v5e constants).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    flops: float              # dense peak FLOP/s per device (bf16/fp16)
    hbm_bw: float             # B/s per device
    hbm_cap: float            # bytes per device
    link_bw: float            # intra-node interconnect B/s per device
    link_alpha: float         # per-collective latency (s)
    inter_bw: float = 0.0     # cross-node B/s per device (PP transport)
    mxu_eff: float = 0.55     # achievable fraction of peak on GEMMs
    bw_eff: float = 0.75      # achievable fraction of HBM bandwidth


H200 = Hardware(name="h200-sxm", flops=989e12, hbm_bw=4.8e12, hbm_cap=141e9,
                link_bw=450e9, link_alpha=4e-6, inter_bw=60e9)
V5E = Hardware(name="tpu-v5e", flops=197e12, hbm_bw=819e9, hbm_cap=16e9,
               link_bw=50e9, link_alpha=1e-6, inter_bw=50e9)

# per-microbatch-pass pipeline overhead (stage hand-off, host-driven step
# launch; vLLM PP's known decode tax). Calibrated on the paper's 14B
# PP2+TP4 = 3.5x-DP8 and 405B PP8 = 7.6x-TP8 points.
PP_PASS_OVERHEAD = {"h200-sxm": 5e-3, "tpu-v5e": 2e-3}


@dataclasses.dataclass(frozen=True)
class ParallelismPlan:
    dp: int = 1
    tp: int = 1
    pp: int = 1
    ep: int = 1               # expert parallel degree (folded into tp domain)

    @property
    def devices(self) -> int:
        return self.dp * self.tp * self.pp

    def label(self) -> str:
        parts = [f"DP={self.dp}"] if self.dp > 1 else []
        if self.tp > 1:
            parts.append(f"TP={self.tp}")
        if self.pp > 1:
            parts.append(f"PP={self.pp}")
        return "+".join(parts) or "DP=1"


def weight_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    return cfg.param_count() * dtype_bytes


def kv_bytes(cfg: ModelConfig, tokens: int, dtype_bytes: int = 2,
             n_seqs: int = 1) -> float:
    """Cache footprint of `n_seqs` sequences totalling `tokens` of context:
    per-token KV across all attention layers plus the constant per-sequence
    recurrent state (SSM/xLSTM/conv)."""
    return cfg.kv_bytes_per_token(dtype_bytes) * tokens \
        + cfg.state_bytes_per_seq(dtype_bytes) * n_seqs


def kv_capacity_tokens(cfg: ModelConfig, plan: ParallelismPlan, hw: Hardware,
                       dtype_bytes: int = 2, overhead: float = 0.10,
                       cache_dtype_bytes: int = 2) -> int:
    """Tokens of KV that fit per replica after weights + runtime overhead.
    TP/PP shard both weights and cache; DP replicates weights (Obs 3)."""
    shard = plan.tp * plan.pp
    w = weight_bytes(cfg, dtype_bytes) / shard
    free = hw.hbm_cap * (1 - overhead) - w
    per_tok = kv_bytes(cfg, 1, cache_dtype_bytes, n_seqs=0) / shard
    if per_tok <= 0:                          # attention-free: state-bound
        return 10 ** 12
    return max(int(free / per_tok), 0)


def _tp_eff(tp: int) -> float:
    """Small-GEMM efficiency decay under TP sharding (per-GPU matmul shrinks;
    calibrated so DP beats TP for <=14B as in paper Fig 8/9)."""
    return 1.0 - 0.10 * math.log2(max(tp, 1))


def _collective_time(bytes_payload: float, n: int, hw: Hardware,
                     kind: str = "all-reduce") -> float:
    """alpha-beta ring model: latency scales with ring steps — the sync cost
    that penalises high-degree TP for low-arithmetic-intensity (MoE) models
    (paper Obs 6)."""
    if n <= 1:
        return 0.0
    factor = {"all-reduce": 2 * (n - 1) / n, "all-gather": (n - 1) / n,
              "all-to-all": (n - 1) / n}[kind]
    steps = {"all-reduce": 2 * (n - 1), "all-gather": n - 1,
             "all-to-all": n - 1}[kind]
    return bytes_payload * factor / hw.link_bw + steps * hw.link_alpha


def prefill_step_time(cfg: ModelConfig, tokens: int, plan: ParallelismPlan,
                      hw: Hardware, dtype_bytes: int = 2) -> Dict[str, float]:
    """One chunked-prefill iteration over `tokens` batched tokens."""
    n_act = cfg.active_param_count()
    t_compute = 2 * n_act * tokens / (plan.tp * plan.pp * hw.flops
                                      * hw.mxu_eff * _tp_eff(plan.tp))
    t_mem = weight_bytes(cfg, dtype_bytes) / (plan.tp * plan.pp) \
        / (hw.hbm_bw * hw.bw_eff)
    # TP: 2 all-reduces of activations per layer
    ar_bytes = tokens * cfg.d_model * dtype_bytes
    t_tp = 2 * cfg.n_layers * _collective_time(ar_bytes, plan.tp, hw) \
        / plan.pp
    if cfg.moe and cfg.moe.n_experts:
        a2a = tokens * cfg.d_model * dtype_bytes * cfg.moe.top_k
        t_tp += 2 * cfg.n_layers * _collective_time(a2a, max(plan.ep, plan.tp),
                                                    hw, "all-to-all") / plan.pp
    return {"compute": t_compute, "memory": t_mem, "comm": t_tp,
            "total": max(t_compute, t_mem) + t_tp}


MOE_SYNC_ALPHA = 160e-6   # calibrated to the paper's R1 TP8 sync pathology
                          # (§V-C Obs 6): per-collective host+launch+a2a
                          # latency for non-graphed MoE layers, scaling
                          # linearly with group size / 2.


def decode_step_time(cfg: ModelConfig, batch: int, mean_context: float,
                     plan: ParallelismPlan, hw: Hardware,
                     dtype_bytes: int = 2,
                     cache_dtype_bytes: int = 2) -> Dict[str, float]:
    """One decode *round* (every running sequence gains one token).

    Pipeline parallelism re-reads each stage's weights once per micro-batch:
    with m = min(pp, batch) micro-batches in flight, per-device weight
    traffic is m x (W / (tp*pp)) per round — the paper's dense-PP decode
    pathology. If m < pp, (pp-m)/pp of stage-steps are bubbles.
    """
    shard = plan.tp * plan.pp
    n_act = cfg.active_param_count()
    w_dev = weight_bytes(cfg, dtype_bytes) / shard
    m_micro = max(min(plan.pp, batch), 1)
    if cfg.moe and cfg.moe.n_experts:
        # only experts hit by a micro-batch are read
        mo = cfg.moe
        per_micro = max(batch // m_micro, 1)
        e_hit = min(mo.n_experts, per_micro * mo.top_k)
        expert_w = mo.n_experts * 3 * cfg.d_model * mo.d_ff_expert \
            * dtype_bytes * (cfg.n_layers - mo.first_dense_layers)
        w_dev = (weight_bytes(cfg, dtype_bytes) - expert_w
                 + expert_w * e_hit / mo.n_experts) / shard
    w_read = w_dev * m_micro                     # PP re-read multiplier
    cache_read = (cfg.kv_bytes_per_token(cache_dtype_bytes) * mean_context
                  * batch + cfg.state_bytes_per_seq(cache_dtype_bytes)
                  * batch) / shard
    # weight streams lose achieved bandwidth as slicing deepens (small
    # per-device GEMV strides); paged cache reads keep full bandwidth
    w_bw = hw.hbm_bw * hw.bw_eff * _tp_eff(shard)
    t_mem = w_read / w_bw + cache_read / (hw.hbm_bw * hw.bw_eff)
    if m_micro < plan.pp:                        # unfillable bubbles
        t_mem *= plan.pp / m_micro
    if plan.pp > 1:
        t_mem += m_micro * PP_PASS_OVERHEAD.get(hw.name, 2e-3)
    t_compute = 2 * n_act * batch / (shard * hw.flops * hw.mxu_eff
                                     * _tp_eff(plan.tp))
    ar_bytes = batch * cfg.d_model * dtype_bytes
    t_tp = 2 * cfg.n_layers * _collective_time(ar_bytes, plan.tp, hw) / plan.pp
    if cfg.moe and cfg.moe.n_experts:
        a2a = batch * cfg.d_model * dtype_bytes * cfg.moe.top_k
        t_tp += 2 * cfg.n_layers * _collective_time(
            a2a, max(plan.ep, plan.tp), hw, "all-to-all") / plan.pp
        # calibrated MoE sync overhead (dispatch/combine per layer, both
        # sub-collectives), linear in the sync-domain size
        n_sync = max(plan.tp, plan.ep)
        t_tp += 4 * cfg.n_layers * MOE_SYNC_ALPHA * (n_sync / 2) / plan.pp \
            if n_sync > 1 else 0.0
    return {"compute": t_compute, "memory": t_mem, "comm": t_tp,
            "total": max(t_compute, t_mem) + t_tp}


def pp_bubble_factor(cfg: ModelConfig, plan: ParallelismPlan, hw: Hardware,
                     batch: int, mean_context: float,
                     dtype_bytes: int = 2) -> float:
    """GPipe-style bubble overhead (p-1)/m, with the micro-batch depth m
    CAPPED by per-stage KV capacity — the paper's 405B pathology (§V-C):
    dense models' KV starves the pipeline of micro-batches."""
    if plan.pp <= 1:
        return 1.0
    cap_tokens = kv_capacity_tokens(cfg, plan, hw, dtype_bytes)
    per_seq = max(mean_context, 1.0)
    max_seqs_in_flight = max(int(cap_tokens / per_seq), 1)
    m = max(min(batch, max_seqs_in_flight) // max(batch // (plan.pp * 4), 1), 1)
    m = min(m, 4 * plan.pp)
    return 1.0 + (plan.pp - 1) / m


def pp_transport_time(cfg: ModelConfig, tokens: int, plan: ParallelismPlan,
                      hw: Hardware, dtype_bytes: int = 2) -> float:
    if plan.pp <= 1:
        return 0.0
    bw = hw.inter_bw or hw.link_bw
    return (plan.pp - 1) * tokens * cfg.d_model * dtype_bytes / bw


def weight_load_time(cfg: ModelConfig, plan: ParallelismPlan, hw: Hardware,
                     dtype_bytes: int = 2) -> float:
    """Cold-start cost of minting a replica: stream each device's weight
    shard into HBM at achievable bandwidth. This is the ingest *lower bound*
    (weights already staged host-side); container pull / checkpoint fetch are
    workload-dependent and modeled separately (the autoscaler's
    ``cold_start_extra_s``). TP/PP shard the weights, so deeper slicing
    loads faster per device — another face of the DP weight-replication tax
    (Obs 3)."""
    return weight_bytes(cfg, dtype_bytes) \
        / (plan.tp * plan.pp * hw.hbm_bw * hw.bw_eff)


def kv_transfer_time(cfg: ModelConfig, context_tokens: int, hw: Hardware,
                     cache_dtype_bytes: int = 2, n_seqs: int = 1) -> float:
    """Prefill→decode migration cost in a disaggregated deployment: ship the
    request's whole KV cache (plus any recurrent state) across the inter-node
    fabric. Strictly monotone in context length; the alpha term models the
    per-transfer handshake/launch latency."""
    payload = kv_bytes(cfg, context_tokens, cache_dtype_bytes, n_seqs=n_seqs)
    bw = hw.inter_bw or hw.link_bw
    return payload / bw + hw.link_alpha
