"""KV-aware admission control (paper Observations 1 & 8) and multi-tenant
SLO-class policy.

The paper's finding: admitting on *current* memory usage lets long-decode
requests blow through HBM later ("the reasoning cliff ... sometimes limiting
admission during prefill"). The KV-aware policy reserves headroom for the
*predicted* decode growth of everything already running before admitting more.

``ClassPolicy`` adds the multi-tenant tier semantics on top: SLO classes carry
an urgency (interactive > batch), the most urgent class(es) may draw on a
reserved KV headroom slice that lower tiers cannot, and the scheduler uses the
same urgencies for waiting-queue order and preemption-victim choice — batch
absorbs backpressure first, interactive latency stays flat under load (the
fleet-level latency-vs-throughput tier trade-off)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.kv_cache import PagedAllocator
from repro.core.request import Request


@dataclasses.dataclass
class ClassPolicy:
    """Scheduling semantics of the SLO classes one engine serves.

    ``priority`` maps class name -> urgency (higher = more latency-critical;
    unknown/untagged classes get 0). ``kv_headroom`` is the pool fraction
    only top-urgency requests may use: lower tiers admit against a budget
    shrunk by that slice, so under pressure batch queues while interactive
    still admits. With no priorities (single-tenant) every class is top
    urgency and behaviour is identical to the class-blind policy."""
    priority: Dict[str, int] = dataclasses.field(default_factory=dict)
    kv_headroom: float = 0.0

    def urgency(self, slo_class: str) -> int:
        return self.priority.get(slo_class, 0)

    def max_urgency(self) -> int:
        return max(self.priority.values(), default=0)

    def protected(self, slo_class: str) -> bool:
        """May this class draw on the reserved KV headroom slice?"""
        return self.urgency(slo_class) >= self.max_urgency()

    def normalized_urgency(self, slo_class: str) -> float:
        """Urgency scaled to [0, 1] *relative to the least urgent known
        class* — urgency measures differentiation, so uniform priorities
        (single-tenant, or every class at one level) normalise to 0 and
        routing/dispatch stay class-blind, exactly like empty priorities."""
        if not self.priority:
            return 0.0
        lo, hi = min(self.priority.values()), max(self.priority.values())
        if hi <= lo:
            return 0.0
        return max(0.0, (self.urgency(slo_class) - lo) / (hi - lo))


@dataclasses.dataclass
class OSLEstimator:
    """EWMA of observed output lengths, seeded with a prior (the Natural-
    Reasoning profile: ~45% of responses exceed 5k tokens)."""
    prior: float = 4000.0
    alpha: float = 0.05
    _est: Optional[float] = None

    def observe(self, osl: int):
        self._est = osl if self._est is None else \
            (1 - self.alpha) * self._est + self.alpha * osl

    def predict_tokens(self, max_new: int) -> float:
        est = self._est if self._est is not None else self.prior
        return min(est, max_new)

    def predict(self, req: Request) -> float:
        return self.predict_tokens(req.max_new_tokens)


@dataclasses.dataclass
class AdmissionPolicy:
    """mode:
      naive    — admit while a prefill page fits (paper's baseline behaviour)
      kv_aware — admit only if predicted peak KV of running+candidate fits in
                 (1 - reserve) of the pool (Obs 1/8 recommendation)

    ``classes`` layers the multi-tenant tiers on top of either mode: a
    non-top-urgency candidate admits against a budget shrunk by the
    ``kv_headroom`` slice reserved for the most urgent class.
    """
    mode: str = "kv_aware"
    reserve: float = 0.05
    estimator: OSLEstimator = dataclasses.field(default_factory=OSLEstimator)
    classes: ClassPolicy = dataclasses.field(default_factory=ClassPolicy)

    def admit(self, req: Request, running: List[Request],
              alloc: PagedAllocator) -> bool:
        # tier slice: a lower-urgency candidate may not fill the headroom
        # reserved for the most urgent class (batch backpressures first)
        slice_ = 0.0 if self.classes.protected(req.slo_class) \
            else self.classes.kv_headroom
        if self.mode == "naive":
            used = alloc.n_pages - alloc.free_pages
            return used + alloc.pages_for(min(req.isl, 1)) \
                < alloc.n_pages * (1.0 - slice_)
        budget = alloc.n_pages * (1.0 - self.reserve - slice_)
        need = 0.0
        for r in [*running, req]:
            # predicted PEAK context: prompt + max(predicted OSL, already
            # generated) — Obs 8: "estimate future KV growth at admission
            # time ... instead of admitting on current memory usage"
            predicted = r.isl + max(self.estimator.predict(r), r.generated)
            need += alloc.pages_for(int(predicted) + 1)
        return need <= budget
