"""KV-aware admission control (paper Observations 1 & 8).

The paper's finding: admitting on *current* memory usage lets long-decode
requests blow through HBM later ("the reasoning cliff ... sometimes limiting
admission during prefill"). The KV-aware policy reserves headroom for the
*predicted* decode growth of everything already running before admitting more.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.kv_cache import PagedAllocator
from repro.core.request import Request


@dataclasses.dataclass
class OSLEstimator:
    """EWMA of observed output lengths, seeded with a prior (the Natural-
    Reasoning profile: ~45% of responses exceed 5k tokens)."""
    prior: float = 4000.0
    alpha: float = 0.05
    _est: Optional[float] = None

    def observe(self, osl: int):
        self._est = osl if self._est is None else \
            (1 - self.alpha) * self._est + self.alpha * osl

    def predict_tokens(self, max_new: int) -> float:
        est = self._est if self._est is not None else self.prior
        return min(est, max_new)

    def predict(self, req: Request) -> float:
        return self.predict_tokens(req.max_new_tokens)


@dataclasses.dataclass
class AdmissionPolicy:
    """mode:
      naive    — admit while a prefill page fits (paper's baseline behaviour)
      kv_aware — admit only if predicted peak KV of running+candidate fits in
                 (1 - reserve) of the pool (Obs 1/8 recommendation)
    """
    mode: str = "kv_aware"
    reserve: float = 0.05
    estimator: OSLEstimator = dataclasses.field(default_factory=OSLEstimator)

    def admit(self, req: Request, running: List[Request],
              alloc: PagedAllocator) -> bool:
        if self.mode == "naive":
            return alloc.free_pages > alloc.pages_for(
                min(req.isl, 1))
        budget = alloc.n_pages * (1.0 - self.reserve)
        need = 0.0
        for r in [*running, req]:
            # predicted PEAK context: prompt + max(predicted OSL, already
            # generated) — Obs 8: "estimate future KV growth at admission
            # time ... instead of admitting on current memory usage"
            predicted = r.isl + max(self.estimator.predict(r), r.generated)
            need += alloc.pages_for(int(predicted) + 1)
        return need <= budget
