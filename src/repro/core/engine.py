"""The inference engine: continuous batching + paged KV + chunked prefill +
preemption + KV-aware admission + online concurrency tuning, with identical
scheduling logic over a real JAX runner or the virtual-clock simulator.

Open-loop replay: ``submit(arrival=t)`` with a future ``t`` holds the request
in a pending heap, invisible to the scheduler until the engine clock reaches
``t`` (the cluster layer's arrival-time gating). ``eject``/``inject`` are the
request hand-off hooks the disaggregated prefill/decode runtime uses to
migrate a prefill-complete request between engines."""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.admission import AdmissionPolicy, ClassPolicy
from repro.core.autotuner import AutotunerConfig, ConcurrencyAutotuner
from repro.core.kv_cache import PagedAllocator
from repro.core.metrics import MetricsLog
from repro.core.request import Request, State
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.trace.events import EventEmitter, EventLog


@dataclasses.dataclass
class EngineConfig:
    n_pages: int = 4096
    page_size: int = 16
    max_num_seqs: int = 256
    max_num_batched_tokens: int = 2048
    chunk_size: int = 512
    admission_mode: str = "kv_aware"     # naive | kv_aware
    autotune: bool = False
    snapshot_every: int = 1
    prefill_only: bool = False           # disaggregated prefill worker
    # multi-tenant SLO classes: name -> urgency (higher = more latency-
    # critical), and the pool fraction only top-urgency requests may use
    class_priorities: Dict[str, int] = dataclasses.field(default_factory=dict)
    class_kv_headroom: float = 0.0
    # dynamic invariant checks (repro.lint.sanitizer) after every step;
    # read-only, so metrics stay bit-identical to the default path
    sanitize: bool = False


class InferenceEngine:
    def __init__(self, cfg_model: ModelConfig, ecfg: EngineConfig, runner,
                 virtual_clock: bool = True, rid_source=None):
        self.cfg_model = cfg_model
        self.ecfg = ecfg
        self.runner = runner
        self.alloc = PagedAllocator(ecfg.n_pages, ecfg.page_size)
        self.sched = Scheduler(
            SchedulerConfig(ecfg.max_num_seqs, ecfg.max_num_batched_tokens,
                            ecfg.chunk_size, prefill_only=ecfg.prefill_only),
            self.alloc, AdmissionPolicy(
                mode=ecfg.admission_mode,
                classes=ClassPolicy(priority=dict(ecfg.class_priorities),
                                    kv_headroom=ecfg.class_kv_headroom)))
        self.virtual_clock = virtual_clock
        self.now = 0.0
        # the event spine (repro.trace): every transition this engine (or
        # its scheduler/allocator) performs is emitted exactly once on this
        # log; metrics are a subscriber, not a parallel bookkeeping path
        self.events = EventLog()
        self.emitter = EventEmitter(self.events, clock=lambda: self.now)
        self.alloc.emitter = self.emitter
        self.sched.emitter = self.emitter
        self.metrics = MetricsLog()
        self.events.subscribe(self.metrics.on_event)
        # rid_source: share one counter across engines whose requests may
        # migrate between them (rids key the paged allocator tables)
        self._rid = rid_source if rid_source is not None else itertools.count()
        self._pending: List = []         # (arrival, rid, Request) min-heap
        self._gen_total = 0
        self._prefill_total = 0
        self._steps = 0
        self.autotuner = ConcurrencyAutotuner(
            AutotunerConfig(enabled=ecfg.autotune), ecfg.max_num_seqs)
        self._sanitizer = None
        if ecfg.sanitize:
            from repro.lint.sanitizer import EngineSanitizer
            self._sanitizer = EngineSanitizer(self)

    # ------------------------------------------------------------------ api
    def submit(self, prompt, max_new_tokens: int,
               arrival: Optional[float] = None,
               slo_class: str = "") -> Request:
        if isinstance(prompt, int):
            prompt = [1] * prompt        # synthetic token ids (sim mode)
        req = Request(rid=next(self._rid), prompt=list(prompt),
                      max_new_tokens=max_new_tokens,
                      arrival=self.now if arrival is None else arrival,
                      slo_class=slo_class)
        # validation runs BEFORE the arrival event on both paths — a
        # rejected request must never reach the stream (the metrics
        # subscriber would log it as a phantom SLO miss)
        if req.arrival > self.now:
            self.sched.validate(req)     # fail fast, like sched.submit
            heapq.heappush(self._pending, (req.arrival, req.rid, req))
        else:
            self.sched.submit(req)       # validates internally
        self.emitter.emit("arrival", rid=req.rid, ref=req, isl=req.isl,
                          max_new_tokens=req.max_new_tokens,
                          arrival=req.arrival, slo_class=req.slo_class)
        return req

    def issued_rids(self) -> List[int]:
        """Every rid this engine currently knows about (for seeding a shared
        fleet-wide counter past them)."""
        reqs = [*self.sched.running, *self.sched.waiting,
                *self.metrics.finished, *(p[2] for p in self._pending)]
        return [r.rid for r in reqs]

    def adopt_rid_source(self, source):
        """Share a fleet-wide rid counter (migration moves requests between
        engines, and rids key the paged-allocator tables)."""
        self._rid = source

    @property
    def has_work(self) -> bool:
        return self.sched.has_work or bool(self._pending)

    def next_arrival(self) -> Optional[float]:
        return self._pending[0][0] if self._pending else None

    def advance_to(self, t: float):
        """Fast-forward an idle clock (no in-flight work ages)."""
        self.now = max(self.now, t)

    def _release_arrivals(self):
        while self._pending and self._pending[0][0] <= self.now:
            self.sched.submit(heapq.heappop(self._pending)[2])

    def eject(self, req: Request) -> Request:
        """Remove a request from this engine without finishing it (the
        disaggregated hand-off: its KV pages are freed here and re-allocated
        on the target via ``inject``). The request leaves this engine's
        submitted log too — per-engine SLO accounting covers requests the
        engine is responsible for finishing; the adopter records it on
        inject (fleet-level accounting lives in ClusterMetrics)."""
        if req in self.sched.running:
            self.sched.running.remove(req)
        elif req in self.sched.waiting:
            self.sched.waiting.remove(req)
        self.alloc.free(req.rid)
        self.emitter.emit("eject", rid=req.rid, ref=req,
                          generated=req.generated,
                          context_tokens=req.context_len)
        if not self.virtual_clock:
            self.runner.release(req)
        return req

    def inject(self, req: Request) -> bool:
        """Adopt a migrated prefill-complete request into the running set.
        Returns False when no KV/concurrency room (caller retries later)."""
        if not self.sched.inject_running(req):
            return False
        self.emitter.emit("inject", rid=req.rid, ref=req,
                          context_tokens=req.context_len)
        return True

    def step(self) -> bool:
        """One engine iteration. Returns False when idle."""
        self._release_arrivals()
        if not self.sched.has_work:
            nxt = self.next_arrival()
            if nxt is None:
                return False
            # open-loop idle gap: jump to the next arrival
            self.advance_to(nxt)
            self._release_arrivals()
        # lint: disable=REP002 (real-execution timing, not simulation)
        # (virtual-clock runs never read t0: the `if self.virtual_clock`
        # branch below uses the runner's modeled iteration_time instead)
        t0 = time.monotonic()
        plan = self.sched.plan_step()
        for r in plan.admitted:
            if r.t_admitted is None:
                r.t_admitted = self.now

        # --- execute prefill chunks (the completing chunk emits a token,
        #     vLLM-style: recompute-resume also re-emits its next token)
        completed_prefill = []
        for req, chunk in plan.prefill:
            completing = req.prompt_pos + chunk >= req.prefill_target
            if completing and not self.virtual_clock:
                tok = self.runner.prefill(req, chunk)
            else:
                tok = 0
            req.prompt_pos += chunk
            self._prefill_total += chunk
            if completing:
                # recompute-resume done: fold the regenerated prefix back out
                # of prompt_pos, else context_len double-counts it forever
                # (each resumed request would hold ~resume_extra phantom KV
                # tokens, inflating pool pressure for its whole decode)
                req.prompt_pos -= req.resume_extra
                req.resume_extra = 0
                req.output.append(tok)
                req.generated += 1
                self._gen_total += 1
                completed_prefill.append(req)
            self.emitter.emit("prefill", rid=req.rid, ref=req, chunk=chunk,
                              completing=completing)

        # --- execute decode batch
        if plan.decode and not self.virtual_clock:
            toks = self.runner.decode(plan.decode)
            for r, t in zip(plan.decode, toks):
                r.output.append(t)
                r.generated += 1
        elif plan.decode:
            for r in plan.decode:
                r.output.append(0)
                r.generated += 1
        self._gen_total += len(plan.decode)
        if plan.decode:
            self.emitter.emit("decode_step",
                              rids=[r.rid for r in plan.decode])

        # --- advance the clock
        if self.virtual_clock:
            dt, parts = self.runner.iteration_time(plan.prefill_tokens,
                                                   plan.decode)
            self.now += dt
            hbm_busy = self.runner.hbm_busy_fraction(parts, dt) \
                if dt else 0.0
        else:
            # lint: disable=REP002 (real-execution path: wall time IS now)
            # (the virtual-clock branch above never reaches this line)
            self.now += time.monotonic() - t0
            hbm_busy = 0.0

        # --- timestamps after the iteration completes
        for req in completed_prefill:
            if req.t_first_token is None:
                req.t_first_token = self.now
        for r in plan.decode:
            r.decode_times.append(self.now)

        # --- finish
        for req in [*plan.decode, *completed_prefill]:
            if req in self.sched.running and req.done and req.prefill_done:
                req.t_finished = self.now
                self.sched.finish(req)
                if not self.virtual_clock:
                    self.runner.release(req)
                self.emitter.emit("finish", rid=req.rid, ref=req,
                                  generated=req.generated,
                                  n_preemptions=req.n_preemptions)

        # --- preempted requests lose their runner slot
        if not self.virtual_clock:
            for r in plan.preempted:
                self.runner.release(r)

        # --- telemetry + autotune
        self._steps += 1
        if self._steps % self.ecfg.snapshot_every == 0:
            # the payload is the complete per-step telemetry surface: the
            # repro.obs window folds must be computable from the stream
            # alone (absolute page counts and the live concurrency cap, not
            # just ratios — the cap can move under the autotuner)
            self.emitter.emit(
                "step", running=len(self.sched.running),
                waiting=len(self.sched.waiting),
                kv_util=self.alloc.utilization(),
                kv_frag=self.alloc.internal_fragmentation(),
                gen_tokens=self._gen_total,
                prefill_tokens=self._prefill_total,
                preemptions=self.sched.n_preemptions,
                hbm_busy=hbm_busy,
                kv_pages_used=self.alloc.used_pages,
                kv_pages_free=self.alloc.free_pages,
                max_seqs=self.sched.cfg.max_num_seqs)
        if self.ecfg.autotune:
            self.sched.cfg.max_num_seqs = self.autotuner.update(
                kv_util=self.alloc.utilization(),
                preemptions_total=self.sched.n_preemptions,
                waiting=len(self.sched.waiting),
                running=len(self.sched.running))
        if self._sanitizer is not None:
            self._sanitizer.check()
        return True

    def run(self, max_steps: int = 10 ** 7):
        for _ in range(max_steps):
            if not self.step():
                break
        return self.metrics
