"""repro.lint rules — our actual bug history distilled into AST checks.

Every rule encodes a defect class that shipped (and was hand-found) in a
previous PR of this repo; the rule id is stable and citable from inline
suppressions (``# lint: disable=REP0xx (reason)``). Rules are
``ast.NodeVisitor`` subclasses emitting ``Finding`` rows; ``paths`` scopes a
rule to the package paths where the invariant holds (empty = everywhere).

Catalog (see docs/lint.md for the history behind each):

  REP001  unseeded / global-state RNG in simulation code
  REP002  wall-clock reachable from virtual-clock sim paths
  REP003  iteration over unordered collections (set) in sim code
  REP004  ``id(...)`` used as a key / identity token
  REP005  mutable default argument
  REP006  ``==`` / ``!=`` on virtual-time floats
  REP007  RoutingPolicy / DispatchPolicy / AutoscalePolicy signature drift
  REP008  frozen-spec dataclass mutated outside ``__post_init__``
  REP009  MetricsLog / ClusterMetrics state mutated outside the event spine
  REP010  live engine state read from a decision-plane (policy) module
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    rule_id: str
    path: str
    line: int
    severity: str                 # "error" | "warning"
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule_id}] "
                f"{self.severity}: {self.message}")


# the simulation core: code where determinism invariants must hold
SIM_PATHS = ("repro/core/", "repro/cluster/", "repro/scenario/",
             "repro/data/")

# determinism scope = sim core + everything whose *output feeds* a sim run:
# launch-side sweep/spec enumeration (a shuffled or entropy-seeded sweep
# grid silently changes which scenarios a campaign runs) and the obs folds
# (two same-seed traces must window/classify identically). Rules about
# hidden nondeterminism (REP001 RNG, REP003 unordered iteration) apply
# here; engine-internal invariants (REP006 time-float equality) stay
# sim-scoped.
DET_PATHS = SIM_PATHS + ("repro/launch/", "repro/obs/")


class Rule(ast.NodeVisitor):
    """One lint rule: visit a module AST, emit ``Finding``s via ``report``.

    ``paths`` is a tuple of path substrings gating where the rule applies
    (normalised to "/"); empty applies everywhere. Subclasses override
    visitor methods and call ``self.report(node, message)``.
    """
    rule_id = "REP000"
    severity = "error"
    title = ""
    paths: Tuple[str, ...] = ()

    def __init__(self):
        self.findings: List[Finding] = []
        self._path = ""

    def applies_to(self, path: str) -> bool:
        p = path.replace("\\", "/")
        return not self.paths or any(tok in p for tok in self.paths)

    def run(self, tree: ast.AST, path: str) -> List[Finding]:
        self.findings = []
        self._path = path
        self.visit(tree)
        return self.findings

    def report(self, node: ast.AST, message: str):
        self.findings.append(Finding(
            rule_id=self.rule_id, path=self._path,
            line=getattr(node, "lineno", 0), severity=self.severity,
            message=message))


def _dotted(node: ast.AST) -> str:
    """'np.random.default_rng' for an Attribute/Name chain ('' otherwise)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


class UnseededRNG(Rule):
    """REP001 — all randomness in sim code must thread a seeded
    ``np.random.Generator``. Module-level ``np.random.*`` draws and stdlib
    ``random.*`` share hidden global state (two call sites perturb each
    other's streams — reordering code changes every trace), and
    ``default_rng()`` without a seed is fresh entropy per process (two runs
    of one scenario disagree)."""
    rule_id = "REP001"
    title = "unseeded or global-state RNG in simulation code"
    paths = DET_PATHS

    def visit_Call(self, node: ast.Call):
        name = _dotted(node.func)
        root = name.split(".", 1)[0]
        if name.endswith("random.default_rng") and root in ("np", "numpy"):
            if not node.args and not node.keywords:
                self.report(node, "default_rng() without a seed draws fresh "
                                  "OS entropy per process; pass an explicit "
                                  "seed so runs replay")
        elif ".random." in f"{name}." and root in ("np", "numpy"):
            self.report(node, f"{name}() uses numpy's hidden global RNG; "
                              "thread a seeded np.random.Generator instead")
        elif root == "random" and name.count(".") == 1:
            self.report(node, f"{name}() uses the stdlib global RNG; thread "
                              "a seeded np.random.Generator instead")
        self.generic_visit(node)


class WallClock(Rule):
    """REP002 — ``time.time``/``time.monotonic``/``datetime.now`` reachable
    from simulation paths couples results to host speed: a virtual-clock run
    must be a pure function of (spec, seed). Real measurement code (launch
    CLIs, real-execution engine paths) suppresses with a justification."""
    rule_id = "REP002"
    title = "wall-clock call on a virtual-clock sim path"
    WALL = ("time.time", "time.monotonic", "time.perf_counter",
            "time.process_time", "datetime.now", "datetime.utcnow",
            "datetime.today", "date.today")

    def visit_Call(self, node: ast.Call):
        name = _dotted(node.func)
        if name and any(name == w or name.endswith("." + w)
                        for w in self.WALL):
            self.report(node, f"{name}() reads the wall clock; simulated "
                              "time must come from the virtual clock "
                              "(engine.now)")
        self.generic_visit(node)


def _is_set_expr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Set):
        return "a set literal"
    if isinstance(node, ast.SetComp):
        return "a set comprehension"
    if isinstance(node, ast.Call):
        name = _dotted(node.func)
        if name == "set":
            return "set(...)"
        if name in ("frozenset",):
            return "frozenset(...)"
        if name.endswith((".union", ".intersection", ".difference",
                          ".symmetric_difference")):
            return f"{name.rsplit('.', 1)[1]}(...) (a set)"
    return None


class UnorderedIteration(Rule):
    """REP003 — iterating a set in sim code lets CPython's hash seed pick
    the order; when that order reaches the event heap (worker scan order,
    tie-broken submissions) two identical runs diverge. Sort first, or keep
    a list alongside the membership set."""
    rule_id = "REP003"
    title = "iteration over an unordered collection in simulation code"
    paths = DET_PATHS

    def _check_iter(self, node: ast.AST, it: ast.AST):
        # sorted(set(...)) / sorted({...}) / sum(set) are fine: sorted
        # restores a total order, and the flagged construct is the bare
        # for-loop (min/max/len/any/all are order-insensitive)
        kind = _is_set_expr(it)
        if kind:
            self.report(node, f"iterating {kind}: set order is "
                              "hash-seed-dependent and can reach the event "
                              "loop; sort it or iterate a list")

    def visit_For(self, node: ast.For):
        self._check_iter(node, node.iter)
        self.generic_visit(node)

    def visit_comprehension(self, node: ast.comprehension):
        self._check_iter(node, node.iter)
        self.generic_visit(node)


class IdAsKey(Rule):
    """REP004 — ``id(obj)`` is an address: the GC reuses it the moment the
    object dies, so id-derived names/keys collide across object lifetimes
    (the PR-4 worker-name collision under autoscaler minting). Use a
    monotonic counter or an explicit name."""
    rule_id = "REP004"
    title = "id(...) used as a key or identity token"

    def visit_Call(self, node: ast.Call):
        if isinstance(node.func, ast.Name) and node.func.id == "id" \
                and len(node.args) == 1:
            self.report(node, "id(...) is a reusable address, not an "
                              "identity: a dead object's id transfers to its "
                              "successor; use a monotonic counter or name")
        self.generic_visit(node)


class MutableDefault(Rule):
    """REP005 — a mutable default is one shared object across every call:
    state leaks between requests/engines that look independent."""
    rule_id = "REP005"
    title = "mutable default argument"

    def _check_args(self, node):
        args = node.args
        for arg, default in zip(
                (args.posonlyargs + args.args)[-len(args.defaults):]
                if args.defaults else [], args.defaults):
            self._check_default(arg.arg, default)
        for arg, default in zip(args.kwonlyargs, args.kw_defaults):
            if default is not None:
                self._check_default(arg.arg, default)

    def _check_default(self, name: str, default: ast.AST):
        bad = None
        if isinstance(default, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                                ast.DictComp, ast.SetComp)):
            bad = "a mutable literal"
        elif isinstance(default, ast.Call) \
                and _dotted(default.func) in ("list", "dict", "set",
                                              "bytearray", "defaultdict",
                                              "deque"):
            bad = f"{_dotted(default.func)}(...)"
        if bad:
            self.report(default, f"default for {name!r} is {bad}, shared "
                                 "across all calls; default to None and "
                                 "build inside")

    def visit_FunctionDef(self, node):
        self._check_args(node)
        self.generic_visit(node)

    def visit_AsyncFunctionDef(self, node):
        self._check_args(node)
        self.generic_visit(node)


# names that denote points on the virtual clock (or durations derived from
# it): direct equality on these floats is how the stale-horizon class of bug
# hides — two clocks that "should" coincide differ by 1e-12 after different
# summation orders
_TIME_NAME = re.compile(
    r"^(now|arrival|makespan|horizon|deadline|next_tick"
    r"|t_[a-z0-9_]+|[a-z0-9_]*_time|[a-z0-9_]*_s)$")


class FloatTimeEquality(Rule):
    """REP006 — virtual-time floats accumulate different rounding depending
    on event interleaving; ``==`` on them encodes an invariant that breaks
    at the 1e-12 level. Compare with <=/>= against an epsilon (or a shared
    tolerance helper)."""
    rule_id = "REP006"
    title = "direct ==/!= on virtual-time floats"
    paths = SIM_PATHS

    def _time_like(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Attribute) and _TIME_NAME.match(node.attr):
            return _dotted(node) or node.attr
        if isinstance(node, ast.Name) and _TIME_NAME.match(node.id):
            return node.id
        return None

    def visit_Compare(self, node: ast.Compare):
        operands = [node.left, *node.comparators]
        for op, lhs, rhs in zip(node.ops, operands, operands[1:]):
            if not isinstance(op, (ast.Eq, ast.NotEq)):
                continue
            # `x == None` is a style bug, not a tolerance bug; and equality
            # against a sentinel int like -1 is common — only flag when the
            # OTHER side is a float-ish expression or another time name
            for a, b in ((lhs, rhs), (rhs, lhs)):
                name = self._time_like(a)
                if name is None:
                    continue
                if isinstance(b, ast.Constant) and (
                        b.value is None or isinstance(b.value, (str, bool))):
                    continue
                self.report(node, f"{name!r} is virtual-clock time; == is "
                                  "brittle at float precision — compare "
                                  "against a tolerance")
                break
        self.generic_visit(node)


# the policy duck-type contracts (source of truth for REP007): every
# override must match parameter names, annotations and defaults exactly, or
# call sites using keywords / subclass-agnostic wrappers drift apart
POLICY_CONTRACTS = {
    "RoutingPolicy": {
        "pick": "(self, views: List[WorkerView], prompt_len: int, "
                "max_new: int, urgency: float = 0.0) -> int",
    },
    "DispatchPolicy": {
        "pick": "(self, views: List[WorkerView], req: Request, "
                "urgency: float = 0.0) -> Optional[int]",
    },
    "RebalancePolicy": {
        "decide": "(self, fleet: FleetView) -> Optional[RebalanceDecision]",
    },
    "AutoscalePolicy": {
        "desired_delta": "(self, s: ScalingSignals, n_provisioned: int) "
                         "-> int",
    },
}


def _signature_str(fn) -> str:
    """Canonical '(self, a: T, b: U = d) -> R' string for a FunctionDef."""
    a = fn.args
    parts = []
    pos = a.posonlyargs + a.args
    defaults = [None] * (len(pos) - len(a.defaults)) + list(a.defaults)
    for arg, d in zip(pos, defaults):
        s = arg.arg
        if arg.annotation is not None:
            s += f": {ast.unparse(arg.annotation)}"
        if d is not None:
            s += f" = {ast.unparse(d)}" if arg.annotation is not None \
                else f"={ast.unparse(d)}"
        parts.append(s)
    if a.vararg:
        parts.append("*" + a.vararg.arg)
    for arg, d in zip(a.kwonlyargs, a.kw_defaults):
        s = arg.arg
        if arg.annotation is not None:
            s += f": {ast.unparse(arg.annotation)}"
        if d is not None:
            s += f" = {ast.unparse(d)}"
        parts.append(s)
    if a.kwarg:
        parts.append("**" + a.kwarg.arg)
    sig = "(" + ", ".join(parts) + ")"
    if fn.returns is not None:
        sig += f" -> {ast.unparse(fn.returns)}"
    return sig


class PolicyConformance(Rule):
    """REP007 — policy objects are duck-typed plug points: the runtime calls
    ``pick`` / ``desired_delta`` with keywords, so a subclass that renames,
    un-annotates or re-defaults a parameter works until the first
    keyword/default-relying call site. Overrides (and the bases themselves)
    must match the contract signature exactly."""
    rule_id = "REP007"
    title = "policy duck-type signature drift"

    def visit_ClassDef(self, node: ast.ClassDef):
        contracts = {}
        if node.name in POLICY_CONTRACTS:
            contracts = POLICY_CONTRACTS[node.name]
        else:
            for base in node.bases:
                base_name = _dotted(base).rsplit(".", 1)[-1]
                if base_name in POLICY_CONTRACTS:
                    contracts = {**contracts,
                                 **POLICY_CONTRACTS[base_name]}
        for stmt in node.body:
            if isinstance(stmt, ast.FunctionDef) and stmt.name in contracts:
                want = contracts[stmt.name]
                got = _signature_str(stmt)
                if got != want:
                    self.report(stmt, f"{node.name}.{stmt.name} signature "
                                      f"drifts from the policy contract:\n"
                                      f"      have {got}\n"
                                      f"      want {want}")
        self.generic_visit(node)


def _is_frozen_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        if isinstance(dec, ast.Call) \
                and _dotted(dec.func).endswith("dataclass"):
            for kw in dec.keywords:
                if kw.arg == "frozen" and isinstance(kw.value, ast.Constant) \
                        and kw.value.value is True:
                    return True
    return False


class FrozenSpecMutation(Rule):
    """REP008 — ``object.__setattr__`` is the one sanctioned escape hatch
    for frozen specs, and only inside ``__post_init__`` (normalisation at
    construction). Anywhere else it silently invalidates every consumer's
    assumption that a spec in hand never changes (hash stability, safe
    sharing across fidelities)."""
    rule_id = "REP008"
    title = "frozen-spec dataclass mutated outside __post_init__"

    def visit_Module(self, node: ast.Module):
        self._walk(node.body, in_post_init=False)

    def _walk(self, body, in_post_init: bool):
        for stmt in body:
            if isinstance(stmt, ast.ClassDef):
                frozen = _is_frozen_dataclass(stmt)
                for sub in stmt.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        ok = frozen and sub.name == "__post_init__"
                        self._walk(sub.body, in_post_init=ok)
                    elif isinstance(sub, ast.ClassDef):
                        self._walk([sub], in_post_init=False)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._walk(stmt.body, in_post_init=False)
            else:
                for call in (n for n in ast.walk(stmt)
                             if isinstance(n, ast.Call)):
                    if _dotted(call.func) == "object.__setattr__" \
                            and not in_post_init:
                        self.report(call, "object.__setattr__ on a frozen "
                                          "spec outside __post_init__: "
                                          "specs are immutable after "
                                          "construction — build a new one "
                                          "with dataclasses.replace")


class MetricsBypass(Rule):
    """REP009 — metrics objects are fold-downs of the ``repro.trace`` event
    stream: their ONLY mutation path is ``on_event``, driven by the
    subscribed ``EventLog``. Sim code that pokes metrics state directly
    (calling the retired ``submit``/``finish``/``snapshot``/``note_*``
    mutators, assigning metrics attributes, or appending to metrics
    collections) re-creates the parallel-bookkeeping split the event spine
    exists to kill: the stream and the summaries silently disagree and
    ``repro.trace diff`` can no longer vouch for a run. Emit an event from
    the one place that performs the transition instead."""
    rule_id = "REP009"
    title = "metrics state mutated outside the event spine"
    paths = ("repro/core/", "repro/cluster/", "repro/scenario/")
    # the consumer modules themselves: on_event's folds live here
    EXCLUDE = ("repro/core/metrics.py", "repro/cluster/metrics.py")
    MUTATORS = ("submit", "finish", "snapshot", "on_event",
                "note_migration", "note_scaling")
    COLLECTION_MUT = ("append", "remove", "extend", "insert", "pop",
                      "clear", "update", "add")

    def applies_to(self, path: str) -> bool:
        p = path.replace("\\", "/")
        if any(tok in p for tok in self.EXCLUDE):
            return False
        return super().applies_to(p)

    def visit_Call(self, node: ast.Call):
        name = _dotted(node.func)
        parts = name.split(".")
        last = parts[-1]
        if len(parts) >= 2 and parts[-2] == "metrics" \
                and last in self.MUTATORS:
            self.report(node, f"{name}() mutates metrics state directly; "
                              "accounting derives from the event stream — "
                              "emit the transition's event instead")
        elif last in ("note_migration", "note_scaling"):
            self.report(node, f"{name}(): the note_* mutators are retired; "
                              "scaling/migration records fold out of "
                              "mint/join/retire/drained and "
                              "kv_transfer/inject events")
        elif len(parts) >= 3 and parts[-3] == "metrics" \
                and last in self.COLLECTION_MUT:
            self.report(node, f"{name}() mutates a metrics collection "
                              "behind the event stream's back; emit the "
                              "transition's event instead")
        self.generic_visit(node)

    def _check_target(self, node: ast.AST, target: ast.AST):
        # flag `x.metrics.attr = ...` / `x.metrics.attr += ...`, but not
        # `self.metrics = ...` (wiring the consumer up is construction)
        if isinstance(target, ast.Attribute) \
                and isinstance(target.value, ast.Attribute) \
                and target.value.attr == "metrics":
            self.report(node, f"assignment to "
                              f"{_dotted(target) or target.attr!r} bypasses "
                              "the event stream; metrics state is a fold "
                              "over events — emit one instead")

    def visit_Assign(self, node: ast.Assign):
        for t in node.targets:
            self._check_target(node, t)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        self._check_target(node, node.target)
        self.generic_visit(node)


class DecisionPlaneBypass(Rule):
    """REP010 — policy and signal modules decide on the frozen decision
    plane (``repro.cluster.view``): ``WorkerView``/``FleetView`` snapshots
    are the ONLY fleet state they may read. Reaching through a live worker
    (``.engine``, ``.alloc``, ``.sched``) re-derives KV headroom / queue
    state at the call site — the forked-math drift the unified-view refactor
    deleted (six modules each computing their own headroom, silently
    disagreeing about saturation) — and reads state mid-mutation (policies
    run inside the event loop). Add the missing field to the view instead."""
    rule_id = "REP010"
    title = "live engine state read from a decision-plane module"
    paths = ("repro/cluster/policies.py", "repro/cluster/rebalance.py",
             "repro/cluster/autoscale.py")
    FORBIDDEN = ("engine", "alloc", "sched")

    def visit_Attribute(self, node: ast.Attribute):
        if node.attr in self.FORBIDDEN:
            self.report(node, f"`.{node.attr}` reaches into live engine "
                              "state from a decision-plane module; policies "
                              "and signals read frozen WorkerView/FleetView "
                              "snapshots (repro.cluster.view) — add the "
                              "missing field to the view instead")
        self.generic_visit(node)


ALL_RULES = (UnseededRNG, WallClock, UnorderedIteration, IdAsKey,
             MutableDefault, FloatTimeEquality, PolicyConformance,
             FrozenSpecMutation, MetricsBypass, DecisionPlaneBypass)


def default_rules() -> List[Rule]:
    return [cls() for cls in ALL_RULES]
