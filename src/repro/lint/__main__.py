"""``python -m repro.lint [paths...]`` — exit non-zero on findings."""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.lint.runner import format_json, format_text, lint_paths


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="determinism & feasibility lint for the repro simulator")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON instead of text")
    ap.add_argument("--errors-only", action="store_true",
                    help="exit non-zero only on error-severity findings")
    args = ap.parse_args(argv)

    findings = lint_paths(args.paths or ["src"])
    if args.json:
        print(format_json(findings))
    else:
        print(format_text(findings))
    gating = [f for f in findings
              if not args.errors_only or f.severity == "error"]
    return 1 if gating else 0


if __name__ == "__main__":
    sys.exit(main())
