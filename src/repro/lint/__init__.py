"""repro.lint — determinism & feasibility checks for the simulator.

Three layers, one goal (a virtual-clock run is a pure function of
(spec, seed) and its accounting balances):

- static rules over the source (``repro.lint.rules`` / ``runner``,
  ``python -m repro.lint src/``),
- static feasibility over a spec (``Scenario.check()``),
- dynamic invariants over a running sim (``repro.lint.sanitizer``, enabled
  with ``sanitize=True`` on the engine/cluster).
"""
from repro.lint.rules import ALL_RULES, Finding, Rule, default_rules
from repro.lint.runner import (format_json, format_text, iter_py_files,
                               lint_file, lint_paths, lint_source,
                               parse_suppressions)
from repro.lint.sanitizer import (ClusterSanitizer, EngineSanitizer,
                                  SanitizerError)

__all__ = [
    "ALL_RULES", "Finding", "Rule", "default_rules",
    "lint_source", "lint_file", "lint_paths", "iter_py_files",
    "parse_suppressions", "format_text", "format_json",
    "SanitizerError", "EngineSanitizer", "ClusterSanitizer",
]
