"""Sim sanitizer — dynamic event-loop invariants, checked every step.

The static rules (repro.lint.rules) catch the *patterns* of our historical
bugs; this module catches their *symptoms* at runtime: a virtual clock that
steps backwards, KV pages leaked or double-owned across eject/inject, a
queue entry missing from the submitted log, a worker-second timeline that
contradicts the mint/decommission events.

Every check is strictly read-only over engine/cluster state, so a
``sanitize=True`` run produces metrics bit-identical to the default path
(asserted in tests/test_lint.py) — the sanitizer observes, never steers.

Enable with ``EngineConfig(sanitize=True)`` or
``ClusterRuntime(..., sanitize=True)`` (or ``Scenario.to_engine/to_cluster
(sanitize=True)``); violations raise ``SanitizerError`` at the step that
broke the invariant, not thousands of events later.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.request import State


class SanitizerError(AssertionError):
    """An event-loop invariant broke. The message names the invariant and
    the state that contradicts it."""


def _fail(where: str, msg: str):
    raise SanitizerError(f"[{where}] {msg}")


class EngineSanitizer:
    """Per-engine invariants, checked after each ``step()``:

    - the virtual clock never moves backwards;
    - KV page conservation: free + held pages == pool size, every page
      owned exactly once;
    - only running requests hold page tables, and each table covers its
      request's used tokens;
    - running/waiting are duplicate-free and disjoint, with sane states;
    - the submitted log covers every queued/pending request (eject/inject
      keep the log consistent), finished requests stayed logged, and no
      rid was logged twice.

    The sanitizer is also a *subscriber* of the engine's event spine
    (``repro.trace``): it folds ``kv_alloc``/``kv_free`` into a page-count
    mirror and replays each rid's lifecycle (arrival -> admit -> preempt ->
    resume -> finish / eject / inject) as a state machine, failing at the
    first event that contradicts the stream's own history — a transition
    the stream missed (or double-emitted) shows up as a mirror/state
    divergence even when the engine state itself still looks consistent.
    """

    _LIFECYCLE_OK = {
        "admit": ("queued",),
        "resume": ("preempted",),
        "preempt": ("running",),
        "finish": ("running",),
    }

    def __init__(self, engine, name: str = "engine"):
        self.engine = engine
        self.name = name
        self._last_now: Optional[float] = None
        # stream mirrors, seeded from the allocator at attach time so an
        # engine sanitized mid-run (ClusterSanitizer attaches lazily) does
        # not misread pre-existing tables as stream divergence
        self._stream_pages: Dict[int, int] = {
            rid: len(t) for rid, t in engine.alloc._tables.items()}
        self._stream_state: Dict[int, str] = {}
        self._last_ev_t: Optional[float] = None
        engine.events.subscribe(self.on_event)

    def check(self):
        self._check_clock()
        self._check_kv_conservation()
        self._check_queues()
        self._check_submitted_log()
        # runs LAST: engine-state checks above report corruption with their
        # own (more specific) messages first
        self._check_stream_mirror()

    # --------------------------------------------------------- stream mirror
    def on_event(self, ev):
        if self._last_ev_t is not None and ev.t < self._last_ev_t - 1e-12:
            _fail(self.name, f"event stream clock moved backwards: "
                             f"{self._last_ev_t} -> {ev.t} ({ev.kind})")
        self._last_ev_t = ev.t
        kind, rid = ev.kind, ev.rid
        if kind == "kv_alloc":
            have = self._stream_pages.get(rid, 0) + ev.payload["pages"]
            self._stream_pages[rid] = have
            if have != ev.payload["held"]:
                _fail(self.name, f"kv_alloc stream mirror for rid {rid} has "
                                 f"{have} pages, event says "
                                 f"{ev.payload['held']}")
        elif kind == "kv_free":
            have = self._stream_pages.pop(rid, 0)
            if have != ev.payload["pages"]:
                _fail(self.name, f"kv_free of rid {rid} released "
                                 f"{ev.payload['pages']} pages, stream "
                                 f"mirror held {have}")
        elif kind == "arrival":
            self._stream_state[rid] = "queued"
        elif kind == "inject":
            self._stream_state[rid] = "running"
        elif kind == "eject":
            self._stream_state.pop(rid, None)
        elif kind in self._LIFECYCLE_OK:
            # lifecycle is replayed only for rids whose arrival/inject the
            # stream itself carried (attach-time in-flight rids are exempt)
            state = self._stream_state.get(rid)
            if state is not None:
                if state not in self._LIFECYCLE_OK[kind]:
                    _fail(self.name, f"stream lifecycle of rid {rid}: "
                                     f"{kind!r} while {state!r} (allowed "
                                     f"from {self._LIFECYCLE_OK[kind]})")
                self._stream_state[rid] = "preempted" \
                    if kind == "preempt" else "running"
                if kind == "finish":
                    del self._stream_state[rid]

    def _check_stream_mirror(self):
        actual = {rid: len(t)
                  for rid, t in self.engine.alloc._tables.items()}
        if self._stream_pages != actual:
            diff = {rid: (self._stream_pages.get(rid), actual.get(rid))
                    for rid in set(self._stream_pages) | set(actual)
                    if self._stream_pages.get(rid) != actual.get(rid)}
            _fail(self.name, f"KV stream mirror diverged from the allocator "
                             f"(rid: stream vs actual pages): {diff}")

    # ------------------------------------------------------------ invariants
    def _check_clock(self):
        now = self.engine.now
        if self._last_now is not None and now < self._last_now - 1e-12:
            _fail(self.name, f"virtual clock moved backwards: "
                             f"{self._last_now} -> {now}")
        self._last_now = now

    def _check_kv_conservation(self):
        alloc = self.engine.alloc
        held = sum(len(t) for t in alloc._tables.values())
        free = len(alloc._free)
        if free + held != alloc.n_pages:
            _fail(self.name, f"KV page leak: free({free}) + held({held}) "
                             f"!= pool({alloc.n_pages})")
        owners: Dict[int, str] = {}
        for p in alloc._free:
            if p in owners:
                _fail(self.name, f"page {p} appears twice in the free list")
            owners[p] = "free"
        for rid in sorted(alloc._tables):
            for p in alloc._tables[rid]:
                if p in owners:
                    _fail(self.name, f"page {p} double-owned: "
                                     f"{owners[p]} and rid {rid}")
                owners[p] = f"rid {rid}"
        for rid in sorted(alloc._tables):
            used = alloc._used_tokens.get(rid, 0)
            have = len(alloc._tables[rid])
            if alloc.pages_for(used) > have:
                _fail(self.name, f"rid {rid} uses {used} tokens but holds "
                                 f"only {have} pages "
                                 f"(needs {alloc.pages_for(used)})")

    def _check_queues(self):
        sched = self.engine.sched
        running = list(sched.running)
        waiting = list(sched.waiting)
        run_rids = [r.rid for r in running]
        wait_rids = [r.rid for r in waiting]
        if len(set(run_rids)) != len(run_rids):
            _fail(self.name, f"duplicate rids in running: {run_rids}")
        if len(set(wait_rids)) != len(wait_rids):
            _fail(self.name, f"duplicate rids in waiting: {wait_rids}")
        both = set(run_rids) & set(wait_rids)
        if both:
            _fail(self.name, f"rids both running and waiting: {sorted(both)}")
        for r in running:
            if r.state is not State.RUNNING:
                _fail(self.name, f"rid {r.rid} in running set with state "
                                 f"{r.state}")
        for r in waiting:
            if r.state not in (State.WAITING, State.PREEMPTED):
                _fail(self.name, f"rid {r.rid} in waiting queue with state "
                                 f"{r.state}")
        # only running requests may hold pages (waiting/preempted freed
        # theirs; finished/ejected freed on the way out)
        orphans = set(self.engine.alloc._tables) - set(run_rids)
        if orphans:
            _fail(self.name, f"page tables held by non-running rids: "
                             f"{sorted(orphans)}")
        for r in running:
            used = self.engine.alloc.tokens_of(r.rid)
            cap = r.isl + r.generated + 1
            if used > cap:
                _fail(self.name, f"rid {r.rid} KV tokens {used} exceed "
                                 f"context+1 ({cap})")

    def _check_submitted_log(self):
        m = self.engine.metrics
        sub_rids = [r.rid for r in m.submitted]
        sub_set = set(sub_rids)
        if len(sub_set) != len(sub_rids):
            dupes = sorted({r for r in sub_rids if sub_rids.count(r) > 1})
            _fail(self.name, f"rids submitted twice: {dupes}")
        queued = [*self.engine.sched.running, *self.engine.sched.waiting,
                  *(p[2] for p in self.engine._pending)]
        missing = [r.rid for r in queued if r.rid not in sub_set]
        if missing:
            _fail(self.name, f"queued rids missing from the submitted log "
                             f"(eject/inject accounting): {sorted(missing)}")
        fin_missing = [r.rid for r in m.finished if r.rid not in sub_set]
        if fin_missing:
            _fail(self.name, f"finished rids missing from the submitted "
                             f"log: {sorted(fin_missing)}")


class ClusterSanitizer:
    """Fleet-level invariants, checked every run-loop iteration:

    - every worker's engine invariants (sanitizers are created lazily, so
      autoscale-minted workers are covered from their first step);
    - worker names unique; pools contain only active members of their role
      (warming and draining replicas excluded);
    - lifecycle timeline sane: ``t_active >= t_join``, a decommission stamp
      never precedes the mint or the retirement request (worker-second
      accounting depends on this ordering);
    - in-flight migrations hold no KV pages on any engine (the pages were
      freed at eject, the target allocates at inject) and have
      ``ready >= eject``;
    - the fleet submitted log is duplicate-free;
    - the fleet event stream's scaling lifecycle is ordered per worker:
      ``mint -> join -> retire -> drained``, never skipping backwards (a
      replica that drains without retiring, or joins twice, is a runtime
      bookkeeping bug the summary-level checks cannot see).
    """

    _STAGE = {"mint": 0, "join": 1, "retire": 2, "drained": 3}

    def __init__(self):
        self._engines: Dict[str, EngineSanitizer] = {}
        self._stages: Dict[str, int] = {}
        self._subscribed = False

    def attach(self, rt):
        """Subscribe to the fleet stream. The runtime calls this at
        construction so no lifecycle event predates the subscription;
        ``check`` self-attaches for standalone use."""
        if not self._subscribed:
            rt.events.subscribe(self.on_event)
            self._subscribed = True

    def check(self, rt):
        self.attach(rt)
        for w in rt.workers:
            es = self._engines.get(w.name)
            if es is None:
                es = self._engines[w.name] = EngineSanitizer(
                    w.engine, name=f"worker {w.name}")
            es.check()
        self._check_fleet(rt)
        self._check_lifecycle(rt)
        self._check_migrations(rt)
        self._check_submitted(rt)

    def on_event(self, ev):
        stage = self._STAGE.get(ev.kind)
        if stage is None:
            return
        # workers present at t=0 never mint on-stream: their first lifecycle
        # event is a retire, which is fine — only going backwards (or
        # joining un-minted, draining un-retired) is a violation
        prev = self._stages.get(ev.worker)
        if stage in (1, 3) and prev != stage - 1:
            _fail("fleet", f"worker {ev.worker!r} scaling lifecycle: "
                           f"{ev.kind!r} without a preceding "
                           f"{'mint' if stage == 1 else 'retire'} "
                           f"on the stream")
        if prev is not None and stage <= prev:
            _fail("fleet", f"worker {ev.worker!r} scaling lifecycle moved "
                           f"backwards: stage {prev} -> {ev.kind!r}")
        self._stages[ev.worker] = stage

    # ------------------------------------------------------------ invariants
    def _check_fleet(self, rt):
        names = [w.name for w in rt.workers]
        if len(set(names)) != len(names):
            _fail("fleet", f"duplicate worker names: {names}")
        member: List = [*rt.prefill_pool, *rt.decode_pool, *rt.colocated_pool]
        for w in member:
            if w not in rt.workers:
                _fail("fleet", f"pool member {w.name!r} not in the fleet")
            if w in rt._warming:
                _fail("fleet", f"warming worker {w.name!r} is already in a "
                               f"route/dispatch pool")
            if w.draining:
                _fail("fleet", f"draining worker {w.name!r} still in a "
                               f"route/dispatch pool")
        for pool, role in ((rt.prefill_pool, "prefill"),
                           (rt.decode_pool, "decode"),
                           (rt.colocated_pool, "colocated")):
            for w in pool:
                if w.role != role:
                    _fail("fleet", f"worker {w.name!r} (role {w.role!r}) "
                                   f"sits in the {role} pool")

    def _check_lifecycle(self, rt):
        for w in rt.workers:
            if w.t_active < w.t_join - 1e-12:
                _fail("fleet", f"worker {w.name!r} active at {w.t_active} "
                               f"before joining at {w.t_join}")
            if w.t_retire is not None:
                if w.t_retire < w.t_join - 1e-12:
                    _fail("fleet", f"worker {w.name!r} retired at "
                                   f"{w.t_retire} before joining at "
                                   f"{w.t_join}")
                asked = rt._retire_requested.get(w.name)
                if asked is not None and w.t_retire < asked - 1e-12:
                    _fail("fleet", f"worker {w.name!r} decommissioned at "
                                   f"{w.t_retire}, before the retirement "
                                   f"request at {asked}")

    def _check_migrations(self, rt):
        for m in rt._migrating:
            req = m["req"]
            if m["ready"] < m["eject"] - 1e-12:
                _fail("fleet", f"migration of rid {req.rid} ready at "
                               f"{m['ready']} before its eject at "
                               f"{m['eject']}")
            holders = [w.name for w in rt.workers
                       if req.rid in w.engine.alloc._tables]
            if holders:
                _fail("fleet", f"migrating rid {req.rid} still holds KV "
                               f"pages on {holders} (eject must free them)")

    def _check_submitted(self, rt):
        rids = [r.rid for r in rt.submitted]
        if len(set(rids)) != len(rids):
            dupes = sorted({r for r in rids if rids.count(r) > 1})
            _fail("fleet", f"rids in the fleet submitted log twice: {dupes}")
