"""Sim sanitizer — dynamic event-loop invariants, checked every step.

The static rules (repro.lint.rules) catch the *patterns* of our historical
bugs; this module catches their *symptoms* at runtime: a virtual clock that
steps backwards, KV pages leaked or double-owned across eject/inject, a
queue entry missing from the submitted log, a worker-second timeline that
contradicts the mint/decommission events.

Every check is strictly read-only over engine/cluster state, so a
``sanitize=True`` run produces metrics bit-identical to the default path
(asserted in tests/test_lint.py) — the sanitizer observes, never steers.

Enable with ``EngineConfig(sanitize=True)`` or
``ClusterRuntime(..., sanitize=True)`` (or ``Scenario.to_engine/to_cluster
(sanitize=True)``); violations raise ``SanitizerError`` at the step that
broke the invariant, not thousands of events later.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.request import State


class SanitizerError(AssertionError):
    """An event-loop invariant broke. The message names the invariant and
    the state that contradicts it."""


def _fail(where: str, msg: str):
    raise SanitizerError(f"[{where}] {msg}")


class EngineSanitizer:
    """Per-engine invariants, checked after each ``step()``:

    - the virtual clock never moves backwards;
    - KV page conservation: free + held pages == pool size, every page
      owned exactly once;
    - only running requests hold page tables, and each table covers its
      request's used tokens;
    - running/waiting are duplicate-free and disjoint, with sane states;
    - the submitted log covers every queued/pending request (eject/inject
      keep the log consistent), finished requests stayed logged, and no
      rid was logged twice.
    """

    def __init__(self, engine, name: str = "engine"):
        self.engine = engine
        self.name = name
        self._last_now: Optional[float] = None

    def check(self):
        self._check_clock()
        self._check_kv_conservation()
        self._check_queues()
        self._check_submitted_log()

    # ------------------------------------------------------------ invariants
    def _check_clock(self):
        now = self.engine.now
        if self._last_now is not None and now < self._last_now - 1e-12:
            _fail(self.name, f"virtual clock moved backwards: "
                             f"{self._last_now} -> {now}")
        self._last_now = now

    def _check_kv_conservation(self):
        alloc = self.engine.alloc
        held = sum(len(t) for t in alloc._tables.values())
        free = len(alloc._free)
        if free + held != alloc.n_pages:
            _fail(self.name, f"KV page leak: free({free}) + held({held}) "
                             f"!= pool({alloc.n_pages})")
        owners: Dict[int, str] = {}
        for p in alloc._free:
            if p in owners:
                _fail(self.name, f"page {p} appears twice in the free list")
            owners[p] = "free"
        for rid in sorted(alloc._tables):
            for p in alloc._tables[rid]:
                if p in owners:
                    _fail(self.name, f"page {p} double-owned: "
                                     f"{owners[p]} and rid {rid}")
                owners[p] = f"rid {rid}"
        for rid in sorted(alloc._tables):
            used = alloc._used_tokens.get(rid, 0)
            have = len(alloc._tables[rid])
            if alloc.pages_for(used) > have:
                _fail(self.name, f"rid {rid} uses {used} tokens but holds "
                                 f"only {have} pages "
                                 f"(needs {alloc.pages_for(used)})")

    def _check_queues(self):
        sched = self.engine.sched
        running = list(sched.running)
        waiting = list(sched.waiting)
        run_rids = [r.rid for r in running]
        wait_rids = [r.rid for r in waiting]
        if len(set(run_rids)) != len(run_rids):
            _fail(self.name, f"duplicate rids in running: {run_rids}")
        if len(set(wait_rids)) != len(wait_rids):
            _fail(self.name, f"duplicate rids in waiting: {wait_rids}")
        both = set(run_rids) & set(wait_rids)
        if both:
            _fail(self.name, f"rids both running and waiting: {sorted(both)}")
        for r in running:
            if r.state is not State.RUNNING:
                _fail(self.name, f"rid {r.rid} in running set with state "
                                 f"{r.state}")
        for r in waiting:
            if r.state not in (State.WAITING, State.PREEMPTED):
                _fail(self.name, f"rid {r.rid} in waiting queue with state "
                                 f"{r.state}")
        # only running requests may hold pages (waiting/preempted freed
        # theirs; finished/ejected freed on the way out)
        orphans = set(self.engine.alloc._tables) - set(run_rids)
        if orphans:
            _fail(self.name, f"page tables held by non-running rids: "
                             f"{sorted(orphans)}")
        for r in running:
            used = self.engine.alloc.tokens_of(r.rid)
            cap = r.isl + r.generated + 1
            if used > cap:
                _fail(self.name, f"rid {r.rid} KV tokens {used} exceed "
                                 f"context+1 ({cap})")

    def _check_submitted_log(self):
        m = self.engine.metrics
        sub_rids = [r.rid for r in m.submitted]
        sub_set = set(sub_rids)
        if len(sub_set) != len(sub_rids):
            dupes = sorted({r for r in sub_rids if sub_rids.count(r) > 1})
            _fail(self.name, f"rids submitted twice: {dupes}")
        queued = [*self.engine.sched.running, *self.engine.sched.waiting,
                  *(p[2] for p in self.engine._pending)]
        missing = [r.rid for r in queued if r.rid not in sub_set]
        if missing:
            _fail(self.name, f"queued rids missing from the submitted log "
                             f"(eject/inject accounting): {sorted(missing)}")
        fin_missing = [r.rid for r in m.finished if r.rid not in sub_set]
        if fin_missing:
            _fail(self.name, f"finished rids missing from the submitted "
                             f"log: {sorted(fin_missing)}")


class ClusterSanitizer:
    """Fleet-level invariants, checked every run-loop iteration:

    - every worker's engine invariants (sanitizers are created lazily, so
      autoscale-minted workers are covered from their first step);
    - worker names unique; pools contain only active members of their role
      (warming and draining replicas excluded);
    - lifecycle timeline sane: ``t_active >= t_join``, a decommission stamp
      never precedes the mint or the retirement request (worker-second
      accounting depends on this ordering);
    - in-flight migrations hold no KV pages on any engine (the pages were
      freed at eject, the target allocates at inject) and have
      ``ready >= eject``;
    - the fleet submitted log is duplicate-free.
    """

    def __init__(self):
        self._engines: Dict[str, EngineSanitizer] = {}

    def check(self, rt):
        for w in rt.workers:
            es = self._engines.get(w.name)
            if es is None:
                es = self._engines[w.name] = EngineSanitizer(
                    w.engine, name=f"worker {w.name}")
            es.check()
        self._check_fleet(rt)
        self._check_lifecycle(rt)
        self._check_migrations(rt)
        self._check_submitted(rt)

    # ------------------------------------------------------------ invariants
    def _check_fleet(self, rt):
        names = [w.name for w in rt.workers]
        if len(set(names)) != len(names):
            _fail("fleet", f"duplicate worker names: {names}")
        member: List = [*rt.prefill_pool, *rt.decode_pool, *rt.colocated_pool]
        for w in member:
            if w not in rt.workers:
                _fail("fleet", f"pool member {w.name!r} not in the fleet")
            if w in rt._warming:
                _fail("fleet", f"warming worker {w.name!r} is already in a "
                               f"route/dispatch pool")
            if w.draining:
                _fail("fleet", f"draining worker {w.name!r} still in a "
                               f"route/dispatch pool")
        for pool, role in ((rt.prefill_pool, "prefill"),
                           (rt.decode_pool, "decode"),
                           (rt.colocated_pool, "colocated")):
            for w in pool:
                if w.role != role:
                    _fail("fleet", f"worker {w.name!r} (role {w.role!r}) "
                                   f"sits in the {role} pool")

    def _check_lifecycle(self, rt):
        for w in rt.workers:
            if w.t_active < w.t_join - 1e-12:
                _fail("fleet", f"worker {w.name!r} active at {w.t_active} "
                               f"before joining at {w.t_join}")
            if w.t_retire is not None:
                if w.t_retire < w.t_join - 1e-12:
                    _fail("fleet", f"worker {w.name!r} retired at "
                                   f"{w.t_retire} before joining at "
                                   f"{w.t_join}")
                asked = rt._retire_requested.get(w.name)
                if asked is not None and w.t_retire < asked - 1e-12:
                    _fail("fleet", f"worker {w.name!r} decommissioned at "
                                   f"{w.t_retire}, before the retirement "
                                   f"request at {asked}")

    def _check_migrations(self, rt):
        for m in rt._migrating:
            req = m["req"]
            if m["ready"] < m["eject"] - 1e-12:
                _fail("fleet", f"migration of rid {req.rid} ready at "
                               f"{m['ready']} before its eject at "
                               f"{m['eject']}")
            holders = [w.name for w in rt.workers
                       if req.rid in w.engine.alloc._tables]
            if holders:
                _fail("fleet", f"migrating rid {req.rid} still holds KV "
                               f"pages on {holders} (eject must free them)")

    def _check_submitted(self, rt):
        rids = [r.rid for r in rt.submitted]
        if len(set(rids)) != len(rids):
            dupes = sorted({r for r in rids if rids.count(r) > 1})
            _fail("fleet", f"rids in the fleet submitted log twice: {dupes}")
