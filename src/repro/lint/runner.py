"""repro.lint runner — file walking, suppressions, reporting.

Suppression syntax (trailing or own-line comment)::

    x = time.time()  # lint: disable=REP002 (measuring real compile latency)
    # lint: disable=REP001,REP003 (fixture intentionally exercises both)
    rng = np.random.default_rng()

A trailing comment suppresses its own line; an own-line comment suppresses
the next line. The parenthesized justification is mandatory — a suppression
without one is itself reported as REP000, so every silenced finding carries
a written reason reviewers can audit.
"""
from __future__ import annotations

import ast
import io
import json
import os
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.lint.rules import Finding, Rule, default_rules

_SUPPRESS = re.compile(
    r"#\s*lint:\s*disable=(?P<ids>REP\d{3}(?:\s*,\s*REP\d{3})*)"
    r"(?P<reason>\s*\(.*\))?")


def parse_suppressions(source: str, path: str) \
        -> Tuple[Dict[int, Set[str]], List[Finding]]:
    """Map line -> suppressed rule ids; findings for reason-less pragmas."""
    by_line: Dict[int, Set[str]] = {}
    problems: List[Finding] = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(t.start[0], t.string, t.line) for t in tokens
                    if t.type == tokenize.COMMENT]
    except tokenize.TokenizeError:
        return by_line, problems
    src_lines = source.splitlines()
    for lineno, comment, line in comments:
        m = _SUPPRESS.search(comment)
        if not m:
            continue
        ids = {s.strip() for s in m.group("ids").split(",")}
        reason = (m.group("reason") or "").strip()
        if len(reason) < 3:          # "()" or absent
            problems.append(Finding(
                rule_id="REP000", path=path, line=lineno, severity="error",
                message="suppression missing justification: write "
                        "`# lint: disable=REPxxx (why this is legitimate)`"))
            continue
        # a trailing comment governs its own line; an own-line comment
        # governs the next code line (skipping blanks and further comments,
        # so a pragma can lead a multi-line explanation block)
        target = lineno
        if line.lstrip().startswith("#"):
            target = lineno + 1
            while target <= len(src_lines):
                stripped = src_lines[target - 1].strip()
                if stripped and not stripped.startswith("#"):
                    break
                target += 1
        by_line.setdefault(target, set()).update(ids)
    return by_line, problems


def lint_source(source: str, path: str,
                rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    rules = list(rules) if rules is not None else default_rules()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(rule_id="REP000", path=path, line=e.lineno or 0,
                        severity="error", message=f"syntax error: {e.msg}")]
    suppressed, findings = parse_suppressions(source, path)
    for rule in rules:
        if not rule.applies_to(path):
            continue
        for f in rule.run(tree, path):
            if f.rule_id in suppressed.get(f.line, ()):
                continue
            findings.append(f)
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule_id))


def lint_file(path: str,
              rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    with open(path, encoding="utf-8") as fh:
        return lint_source(fh.read(), path, rules)


def iter_py_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                out.append(p)
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".py"))
    return out


def lint_paths(paths: Iterable[str],
               rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        findings.extend(lint_file(path, rules))
    return findings


def format_text(findings: Sequence[Finding]) -> str:
    lines = [f.format() for f in findings]
    n_err = sum(1 for f in findings if f.severity == "error")
    n_warn = len(findings) - n_err
    lines.append(f"{len(findings)} finding(s): {n_err} error(s), "
                 f"{n_warn} warning(s)")
    return "\n".join(lines)


def format_json(findings: Sequence[Finding]) -> str:
    return json.dumps([vars(f) for f in findings], indent=2)
