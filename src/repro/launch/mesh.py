"""Production meshes. Functions, not module constants — importing this module
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(devices: int, model_parallel: int = 1, pods: int = 1):
    """Elastic helper: lay available devices out as (pod, data, model)."""
    data = devices // (model_parallel * pods)
    assert data * model_parallel * pods == devices, \
        f"{devices} devices don't tile (pods={pods}, tp={model_parallel})"
    if pods > 1:
        return jax.make_mesh((pods, data, model_parallel),
                             ("pod", "data", "model"))
    return jax.make_mesh((data, model_parallel), ("data", "model"))
