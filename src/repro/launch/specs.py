"""Abstract input specs + shardings for every (arch x shape) dry-run cell.

Everything here is ShapeDtypeStruct-based: the production shapes are never
allocated on this host (the smoke tests exercise reduced configs instead).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.registry import ShapeSpec
from repro.models import transformer as T
from repro.parallel.sharding import ParallelContext
from repro.train import optimizer as opt_lib


def build_ctx(mesh, multi_pod: bool, cfg: ModelConfig, shape: ShapeSpec,
              opts: Optional[Dict[str, Any]] = None) -> ParallelContext:
    opts = opts or {}
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    dp = int(np.prod([mesh.shape[a] for a in batch_axes]))
    overrides: Dict[str, Any] = {}
    if shape.kind == "decode" and shape.global_batch % dp != 0:
        # long_500k (B=1): batch unshardable -> shard the cache sequence axis
        overrides.update({"batch": None, "cache_batch": None,
                          "cache_seq": "data"})
    overrides.update(opts.get("rules_override", {}))
    kv_dt = opts.get("kv_cache_dtype")
    if isinstance(kv_dt, str):
        kv_dt = {"int8": jnp.int8, "bf16": jnp.bfloat16,
                 "fp8": jnp.float8_e4m3fn}[kv_dt]
    return ParallelContext(
        mesh=mesh,
        batch_axes=batch_axes,
        fsdp_axis=opts.get("fsdp_axis", "data"),
        remat=opts.get("remat", "full" if shape.kind == "train" else "none"),
        kv_cache_dtype=kv_dt,
        moe_dispatch=opts.get("moe_dispatch", "auto"),
        rules_override=overrides or None,
        decode_unroll=bool(opts.get("decode_unroll")),
        serve_2d_tp=bool(opts.get("serve_2d_tp")),
        seq_parallel_norm=bool(opts.get("seq_parallel_norm")),
        moe_ff_shard=bool(opts.get("moe_ff_shard")),
        seq_shard_decode=bool(opts.get("seq_shard_decode")),
        train_kv_2d=bool(opts.get("train_kv_2d")),
    )


def _tok_lens(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[int, int]:
    """(token_len, prefix_len) so prefix+tokens == shape.seq_len."""
    p = cfg.frontend_prefix_len
    return shape.seq_len - p, p


def input_specs(cfg: ModelConfig, shape: ShapeSpec, ctx: ParallelContext,
                act_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Abstract inputs + NamedShardings for the given cell."""
    mesh = ctx.mesh
    B = shape.global_batch
    s_tok, s_pre = _tok_lens(cfg, shape)
    tok_sh = NamedSharding(mesh, ctx.spec("batch", None))

    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, s_tok), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, s_tok), jnp.int32),
        }
        shardings = {"tokens": tok_sh, "labels": tok_sh}
        if s_pre:
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, s_pre, cfg.d_model), act_dtype)
            shardings["prefix_embeds"] = NamedSharding(
                mesh, ctx.spec("batch", None, None))
        return {"batch": batch, "shardings": shardings}

    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, s_tok), jnp.int32)}
        shardings = {"tokens": tok_sh}
        if s_pre:
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, s_pre, cfg.d_model), act_dtype)
            shardings["prefix_embeds"] = NamedSharding(
                mesh, ctx.spec("batch", None, None))
        return {"batch": out, "shardings": shardings}

    # decode: one new token against a seq_len-deep cache
    state = jax.eval_shape(
        lambda: T.init_decode_state(cfg, ctx, B, shape.seq_len,
                                    ctx.kv_cache_dtype or act_dtype))
    return {
        "batch": {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)},
        "shardings": {"tokens": tok_sh},
        "state": state,
        "state_shardings": state_shardings(cfg, ctx),
    }


def state_pspecs(cfg: ModelConfig, ctx: ParallelContext):
    """PartitionSpec tree matching init_decode_state's structure."""
    sp: Dict[str, Any] = {"lens": ctx.spec("cache_batch")}
    kv_sp = ctx.spec("layers", "cache_batch", "cache_seq", "cache_kv", None)
    mla_sp = ctx.spec("layers", "cache_batch", "cache_seq", None)
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        caches = {}
        n_dense = cfg.moe.first_dense_layers if (cfg.moe and cfg.moe.n_experts) \
            else cfg.n_layers
        n_moe = cfg.n_layers - n_dense if (cfg.moe and cfg.moe.n_experts) else 0
        for name, n in (("dense_stack", n_dense), ("moe_stack", n_moe)):
            if n == 0:
                continue
            if cfg.attention == "mla":
                caches[name] = {"ckv": mla_sp, "kpe": mla_sp}
            else:
                caches[name] = {"k": kv_sp, "v": kv_sp}
        sp["caches"] = caches
    elif cfg.family == "hybrid":
        sp["caches"] = {"shared_attn": {"k": kv_sp, "v": kv_sp}}
        h_sp = ctx.spec("layers", "cache_batch", "ssm_heads", None, None)
        cs_x = ctx.spec("layers", "cache_batch", None, "ssm_inner")
        cs_bc = ctx.spec("layers", "cache_batch", None, None)
        sp["mamba"] = (h_sp, (cs_x, cs_bc, cs_bc))
    elif cfg.family == "ssm":
        two = ctx.spec("layers", "layers")
        def m(*rest):
            return ctx.spec("layers", "layers", "cache_batch", *rest)
        sp["mlstm"] = (m(None, None, None), m(None, None), m(None),
                       m(None, None))
        def s(*rest):
            return ctx.spec("layers", "cache_batch", *rest)
        sp["slstm"] = (s(None), s(None), s(None), s(None))
    return sp


def state_shardings(cfg: ModelConfig, ctx: ParallelContext):
    sp = state_pspecs(cfg, ctx)
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(ctx.mesh, p), sp,
        is_leaf=lambda x: isinstance(x, P))
