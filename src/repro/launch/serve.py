"""Serving launcher.

Real mode (CPU-runnable, reduced config):
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --smoke \
        --requests 8

Simulated fleet mode (paper-scale characterization):
    PYTHONPATH=src python -m repro.launch.serve --arch llama3-405b --sim \
        --hw h200 --tp 8 --requests 2000
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.core import perf_model as pm
from repro.core.engine import EngineConfig, InferenceEngine
from repro.core.router import DPRouter, RouterConfig
from repro.core.runner import JaxRunner, SimRunner
from repro.data.reasoning import REASONING, sample


def build_sim_fleet(cfg, args):
    hw = {"h200": pm.H200, "v5e": pm.V5E}[args.hw]
    plan = pm.ParallelismPlan(dp=args.dp, tp=args.tp, pp=args.pp, ep=args.tp)
    cap = pm.kv_capacity_tokens(cfg, plan, hw)
    ecfg = EngineConfig(n_pages=max(cap // 16, 64),
                        max_num_seqs=args.max_num_seqs,
                        max_num_batched_tokens=args.max_batched_tokens,
                        chunk_size=512, admission_mode=args.admission,
                        autotune=args.autotune)
    replicas = [InferenceEngine(cfg, ecfg, SimRunner(cfg, plan, hw))
                for _ in range(args.dp)]
    return DPRouter(replicas, RouterConfig(policy=args.router))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sim", action="store_true")
    ap.add_argument("--hw", choices=["h200", "v5e"], default="v5e")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--pp", type=int, default=1)
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--max-num-seqs", type=int, default=256)
    ap.add_argument("--max-batched-tokens", type=int, default=8192)
    ap.add_argument("--admission", choices=["naive", "kv_aware"],
                    default="kv_aware")
    ap.add_argument("--router", choices=["round_robin", "jsq", "memory_aware"],
                    default="memory_aware")
    ap.add_argument("--autotune", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.sim:
        cfg = get_config(args.arch)
        router = build_sim_fleet(cfg, args)
        for isl, osl in sample(REASONING, args.requests, seed=args.seed):
            router.submit(int(isl), int(osl), arrival=0.0)
        metrics = router.run_all()
        agg = {}
        for i, m in enumerate(metrics):
            s = m.summary()
            print(f"[replica {i}] done={s['n_finished']} "
                  f"tput={s['gen_throughput_tok_s']:.0f} tok/s "
                  f"ttft_p50={s['ttft_s']['p50']:.2f}s "
                  f"tpot={s['tpot_s']['mean']*1e3:.1f}ms "
                  f"preempt={s['preemptions']}")
        total = sum(m.summary()["gen_tokens"] for m in metrics)
        dur = max(m.summary()["duration_s"] for m in metrics)
        print(f"[fleet] {total} tokens in {dur:.1f}s "
              f"-> {total/dur:.0f} tok/s aggregate")
        return

    # real execution
    import jax
    import jax.numpy as jnp
    from repro.models import transformer as T
    from repro.parallel.sharding import single_device_ctx
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    ctx = single_device_ctx()
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed), ctx,
                           mode="serve", dtype=jnp.float32)
    max_len = 192
    runner = JaxRunner(cfg, params, ctx, max_slots=8, max_len=max_len)
    ecfg = EngineConfig(n_pages=8 * max_len // 16, max_num_seqs=8,
                        max_num_batched_tokens=1024, chunk_size=max_len,
                        admission_mode=args.admission)
    eng = InferenceEngine(cfg, ecfg, runner, virtual_clock=False)
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=int(rng.integers(4, 24)))
        eng.submit(prompt.tolist(), int(rng.integers(8, 32)))
    m = eng.run()
    s = m.summary()
    print(json.dumps({k: v for k, v in s.items() if not isinstance(v, dict)},
                     indent=1))
    print(f"[serve] completed {s['n_finished']} requests, "
          f"{s['gen_tokens']} tokens")


if __name__ == "__main__":
    main()
