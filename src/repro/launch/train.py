"""Training launcher: synthetic-LM training with checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
        --steps 50 --batch 8 --seq 128

Fault tolerance: checkpoints every --ckpt-every steps (async, step-atomic);
on start, resumes from the latest checkpoint if present (elastic: the restore
re-shards onto whatever mesh this process builds).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_smoke_config
from repro.models import transformer as T
from repro.parallel.sharding import ParallelContext, single_device_ctx
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def synthetic_batch(key, batch: int, seq: int, vocab: int):
    tokens = jax.random.randint(key, (batch, seq + 1), 0, vocab)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    ctx = single_device_ctx()
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=20)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key, ctx, mode="train", dtype=jnp.float32)
    opt_state = init_opt_state(params, ocfg)
    start_step = 0
    if args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt_state), start_step = ckpt.restore(
                (params, opt_state), args.ckpt_dir)
            print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, ctx, ocfg))
    pending = None
    # lint: disable=REP002 (real training-loop step timing, not simulation)
    t0 = time.time()
    for step in range(start_step, args.steps):
        key, bk = jax.random.split(key)
        batch = synthetic_batch(bk, args.batch, args.seq, cfg.vocab)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % args.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            print(f"[train] step {step+1:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  # lint: disable=REP002 (real training throughput readout)
                  f"({(time.time()-t0)/(step-start_step+1):.2f}s/step)",
                  flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = ckpt.save_async((params, opt_state), args.ckpt_dir,
                                      step + 1)
    if pending is not None:
        pending.join()
    print(f"[train] done: {args.steps - start_step} steps, "
          f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
