import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Multi-pod dry-run: lower + compile every (arch x shape) cell on the
# production mesh with 512 placeholder host devices (the two lines above MUST
# precede any other import — jax locks the device count at first init).
import argparse    # noqa: E402
import json        # noqa: E402
import time        # noqa: E402
import traceback   # noqa: E402
from pathlib import Path  # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.analysis.hlo import analyze_compiled          # noqa: E402
from repro.configs.registry import (ARCHS, SHAPES, cells,  # noqa: E402
                                    get_config, shape_applicable)
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.launch.specs import build_ctx, input_specs     # noqa: E402
from repro.models import transformer as T                 # noqa: E402
from repro.train import optimizer as opt_lib              # noqa: E402
from repro.train.train_step import (make_decode_step,     # noqa: E402
                                    make_prefill_step, make_train_step)

# v5e hardware constants for the roofline terms (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link (wire-bytes basis)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               opts=None, return_artifacts: bool = False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = build_ctx(mesh, multi_pod, cfg, shape, opts)
    opts = opts or {}
    mode = "train" if shape.kind == "train" else "serve"
    params_dtype = jnp.bfloat16
    aparams = T.abstract_params(cfg, ctx, mode=mode, dtype=params_dtype)
    psh = T.param_shardings(cfg, ctx, mode=mode)
    spec = input_specs(cfg, shape, ctx)
    # lint: disable=REP002 (measuring real lower/compile wall time, not sim)
    t0 = time.time()

    if shape.kind == "train":
        ocfg = opt_lib.AdamWConfig(
            state_dtype=jnp.bfloat16 if opts.get("opt_bf16") else jnp.float32)
        aopt = opt_lib.abstract_opt_state(aparams, ocfg)
        osh = opt_lib.opt_state_shardings(psh, mesh)
        step = make_train_step(cfg, ctx, ocfg)
        jitted = jax.jit(step, in_shardings=(psh, osh, spec["shardings"]),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(aparams, aopt, spec["batch"])
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, ctx, max_len=shape.seq_len)
        args = [aparams, spec["batch"]["tokens"]]
        in_sh = [psh, spec["shardings"]["tokens"]]
        if "prefix_embeds" in spec["batch"]:
            args.append(spec["batch"]["prefix_embeds"])
            in_sh.append(spec["shardings"]["prefix_embeds"])
        jitted = jax.jit(step, in_shardings=tuple(in_sh))
        lowered = jitted.lower(*args)
    else:  # decode
        step = make_decode_step(cfg, ctx)
        jitted = jax.jit(
            step,
            in_shardings=(psh, spec["state_shardings"],
                          spec["shardings"]["tokens"]),
            donate_argnums=(1,))
        lowered = jitted.lower(aparams, spec["state"],
                               spec["batch"]["tokens"])
    t_lower = time.time() - t0    # lint: disable=REP002 (real compile timing)
    t0 = time.time()              # lint: disable=REP002 (real compile timing)
    compiled = lowered.compile()
    t_compile = time.time() - t0  # lint: disable=REP002 (real compile timing)

    n_dev = mesh.size
    res = analyze_compiled(compiled, n_dev)
    res.update({
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "opts": {k: str(v) for k, v in (opts or {}).items()},
    })
    # roofline terms (per device, one step)
    chips = n_dev
    res["roofline"] = roofline_terms(res, cfg, shape)
    if return_artifacts:
        return res, lowered, compiled
    return res


def roofline_terms(res, cfg, shape):
    flops = res["flops"]                      # per device (SPMD program)
    hbm = res["hbm_bytes"]
    wire = res["collective_wire_total"]
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_coll = wire / ICI_BW
    n_dev = res["n_devices"]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens / n_dev

    # analytic fp32 optimizer streaming (outside the strict HLO op set):
    # m read+write, v read+write (fp32) + bf16 param update write
    if shape.kind == "train":
        opt_stream = (4 * 4 + 2) * cfg.param_count() / n_dev
        hbm = hbm + opt_stream
        t_memory = hbm / HBM_BW
        res["hbm_bytes_with_opt"] = hbm

    # analytic must-move bytes per device (lower bound on HBM traffic)
    pbytes = cfg.param_count() * 2 / n_dev                  # bf16 weights
    if shape.kind == "train":
        # fwd+bwd weight reads, grad write, m/v read+write (fp32)
        must_bytes = 2 * pbytes + pbytes + 4 * (cfg.param_count() * 4 / n_dev)
    elif shape.kind == "decode":
        cache = (cfg.kv_bytes_per_token(2) * shape.seq_len
                 + cfg.state_bytes_per_seq(2)) * shape.global_batch / n_dev
        must_bytes = cfg.active_param_count() * 2 / n_dev + cache
    else:  # prefill: read weights, write the cache once
        cache = cfg.kv_bytes_per_token(2) * tokens / n_dev
        must_bytes = pbytes + cache
    # Pallas-kernel-adjusted memory term: flash_core traffic lives in VMEM in
    # the runtime kernel; the kernel's own HBM I/O (q,k,v read + o write) is
    # added back analytically.
    from repro.parallel.sharding import padded_heads
    hp, kvp = padded_heads(cfg.n_heads, cfg.n_kv_heads, 16)
    kvx = kvp if shape.kind != "train" else (
        cfg.n_kv_heads if hp % cfg.n_kv_heads == 0 else kvp)
    hd = cfg.resolved_head_dim
    passes = 4 if shape.kind == "train" else 1
    if shape.kind != "decode" and cfg.n_attention_layers:
        io = (2 * hp * hd + 2 * kvx * hd) * tokens * 2 \
            * cfg.n_attention_layers * passes / n_dev
    else:
        io = 0.0
    hbm_kernel = max(hbm - res.get("flash_scoped_bytes", 0.0) + io, 0.0)
    t_memory_kernel = hbm_kernel / HBM_BW

    dom = max((t_compute, "compute"), (t_memory, "memory"), (t_coll, "collective"))
    eff = {"compute": (model_flops / flops) if flops else 0.0,
           "memory": (must_bytes / hbm) if hbm else 0.0,
           "collective": (res["collective_payload_total"] / wire) if wire else 1.0}
    return {
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "t_memory_kernel_adj_s": t_memory_kernel,
        "hbm_bytes_kernel_adj": hbm_kernel,
        "bottleneck": dom[1],
        "model_flops_per_dev": model_flops,
        "must_bytes_per_dev": must_bytes,
        "useful_flop_ratio": (model_flops / flops) if flops else 0.0,
        "memory_efficiency": eff["memory"],
        "dominant_efficiency": eff[dom[1]],
        # MFU the step would achieve if it ran exactly at the binding roofline
        "roofline_fraction": (model_flops / PEAK_FLOPS) / max(
            t_compute, t_memory, t_coll) if flops else 0.0,
        "step_time_bound_s": max(t_compute, t_memory, t_coll),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opts", default="{}",
                    help='json, e.g. {"opt_bf16": true, "remat": "none"}')
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    opts = json.loads(args.opts)
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        todo = [(a, s) for a, s, skip in cells(include_skipped=True)
                if skip is None]
    else:
        todo = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch, shape in todo:
        for mp in meshes:
            name = f"{arch}__{shape}__{'multi' if mp else 'single'}__{args.tag}"
            path = outdir / f"{name}.json"
            if path.exists() and not args.force:
                print(f"[skip existing] {name}", flush=True)
                continue
            print(f"[dryrun] {name} ...", flush=True)
            try:
                res = lower_cell(arch, shape, mp, opts)
            except Exception as e:  # record failures for triage
                res = {"arch": arch, "shape": shape,
                       "mesh": "multi" if mp else "single",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-4000:]}
            path.write_text(json.dumps(res, indent=1, default=str))
            status = ("ERROR " + res["error"][:120]) if "error" in res else (
                "skipped: " + res["skipped"] if "skipped" in res else
                f"ok flops={res['flops']:.3e} hbm={res['hbm_bytes']:.3e} "
                f"wire={res['collective_wire_total']:.3e} "
                f"bottleneck={res['roofline']['bottleneck']} "
                f"frac={res['roofline']['roofline_fraction']:.3f} "
                f"compile={res['compile_s']}s")
            print(f"[done] {name}: {status}", flush=True)


if __name__ == "__main__":
    main()
