"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory, exp gating).

Both are implemented as exact fp32 recurrences via lax.scan over time with the
stabilizer state m (xLSTM paper eq. 15/24). The recurrent form is
FLOP-equivalent to the chunked form for the matrix memory (O(hd^2) per token
either way) so the roofline compute term is unaffected; a chunked kernel would
only change latency on real hardware (noted in DESIGN.md — xlstm-350m is the
smallest assigned arch and never the fleet bottleneck).

State per sequence is O(1): mLSTM (C (nh,hd,hd), n (nh,hd), m (nh,)) and
sLSTM (c,n,h,m each (d,)) — no KV cache, which is why long_500k runs here.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm


def _mlstm_dims(cfg):
    di = 2 * cfg.d_model             # projection factor 2
    nh = cfg.n_heads
    hd = di // nh
    return di, nh, hd


def init_mlstm_params(key, cfg, dtype=jnp.float32) -> Dict[str, jax.Array]:
    d = cfg.d_model
    di, nh, hd = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones((d,), dtype),
        "w_up": dense_init(ks[0], (d, 2 * di), d, dtype),   # (x_m, ogate path)
        "conv": dense_init(ks[1], (4, di), 4, dtype),
        "w_q": dense_init(ks[2], (di, di), di, dtype),
        "w_k": dense_init(ks[3], (di, di), di, dtype),
        "w_v": dense_init(ks[4], (di, di), di, dtype),
        "w_if": dense_init(ks[5], (di, 2 * nh), di, dtype),
        "gnorm": jnp.ones((di,), dtype),
        "w_down": dense_init(ks[6], (di, d), di, dtype),
        "skip": dense_init(ks[7], (di, di), di, dtype, scale=0.1),
    }


MLSTM_AXES = {
    "norm": ("embed",), "w_up": ("embed", "ssm_inner"), "conv": (None, "ssm_inner"),
    "w_q": ("ssm_inner", None), "w_k": ("ssm_inner", None), "w_v": ("ssm_inner", None),
    "w_if": ("ssm_inner", None), "gnorm": (None,), "w_down": (None, "embed"),
    "skip": ("ssm_inner", None),
}


def init_slstm_params(key, cfg, dtype=jnp.float32) -> Dict[str, jax.Array]:
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    ff = int(round(4 * d / 3 / 64)) * 64
    ks = jax.random.split(key, 4)
    return {
        "norm": jnp.ones((d,), dtype),
        "w_gates": dense_init(ks[0], (d, 4 * d), d, dtype),       # z,i,f,o
        "r_gates": dense_init(ks[1], (4, nh, hd, hd), hd, dtype), # block-diag recurrent
        "gnorm": jnp.ones((d,), dtype),
        "w_up": dense_init(ks[2], (d, 2 * ff), d, dtype),
        "w_down": dense_init(ks[3], (ff, d), ff, dtype),
    }


SLSTM_AXES = {
    "norm": ("embed",), "w_gates": ("embed", None), "r_gates": (None, None, None, None),
    "gnorm": (None,), "w_up": ("embed", "mlp"), "w_down": ("mlp", "embed"),
}


def mlstm_forward(x, p, cfg, *, initial_state=None):
    """x (B,S,d) -> (y (B,S,d), state). Recurrent scan over time."""
    from repro.models.ssm import _causal_conv
    B, S, d = x.shape
    di, nh, hd = _mlstm_dims(cfg)
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    up = xn @ p["w_up"]
    xm, og = up[..., :di], up[..., di:]
    if initial_state is not None:
        conv_cs_in = initial_state[3]
    else:
        conv_cs_in = None
    conv_out, conv_cs = _causal_conv(xm, p["conv"], conv_cs_in)
    conv_act = jax.nn.silu(conv_out)
    q = (conv_act @ p["w_q"]).reshape(B, S, nh, hd).astype(jnp.float32)
    k = ((conv_act @ p["w_k"]) * hd ** -0.5).reshape(B, S, nh, hd).astype(jnp.float32)
    v = (xm @ p["w_v"]).reshape(B, S, nh, hd).astype(jnp.float32)
    gates = (xm @ p["w_if"]).astype(jnp.float32)                      # (B,S,2nh)
    ig, fg = gates[..., :nh], gates[..., nh:]

    if initial_state is None:
        C0 = jnp.zeros((B, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, nh, hd), jnp.float32)
        m0 = jnp.zeros((B, nh), jnp.float32)
    else:
        C0, n0, m0 = initial_state[:3]

    def step(carry, inp):
        C, n, m, = carry
        qt, kt, vt, it, ft = inp
        logf = jax.nn.log_sigmoid(ft)                                 # <= 0
        m_new = jnp.maximum(logf + m, it)
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(it - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * (vt[..., :, None] * kt[..., None, :])
        n = fp[..., None] * n + ip[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)),
                          jnp.exp(-m_new))[..., None]
        return (C, n, m_new), num / den

    xs = tuple(jnp.swapaxes(a, 0, 1) for a in (q, k, v, ig, fg))
    (Cf, nf, mf), h = jax.lax.scan(step, (C0, n0, m0), xs)
    h = jnp.swapaxes(h, 0, 1).reshape(B, S, di).astype(x.dtype)
    h = rmsnorm(h, p["gnorm"], cfg.norm_eps) + conv_act @ p["skip"]
    y = (h * jax.nn.sigmoid(og)) @ p["w_down"]
    return x + y, (Cf, nf, mf, conv_cs)


def mlstm_decode(x, p, cfg, state):
    """x (B,1,d); state (C, n, m, conv_state (B,3,di))."""
    from repro.models.ssm import _causal_conv
    B = x.shape[0]
    di, nh, hd = _mlstm_dims(cfg)
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    up = xn @ p["w_up"]
    xm, og = up[..., :di], up[..., di:]
    conv_out, new_cs = _causal_conv(xm, p["conv"], state[3])
    conv_act = jax.nn.silu(conv_out)
    q = (conv_act @ p["w_q"]).reshape(B, nh, hd).astype(jnp.float32)
    k = ((conv_act @ p["w_k"]) * hd ** -0.5).reshape(B, nh, hd).astype(jnp.float32)
    v = (xm @ p["w_v"]).reshape(B, nh, hd).astype(jnp.float32)
    gates = (xm @ p["w_if"]).astype(jnp.float32)[:, 0]
    it, ft = gates[..., :nh], gates[..., nh:]
    C, n, m = state[:3]
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(it - m_new)
    C = fp[..., None, None] * C + ip[..., None, None] * (v[..., :, None] * k[..., None, :])
    n = fp[..., None] * n + ip[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)),
                      jnp.exp(-m_new))[..., None]
    h = (num / den).reshape(B, 1, di).astype(x.dtype)
    h = rmsnorm(h, p["gnorm"], cfg.norm_eps) + conv_act @ p["skip"]
    y = (h * jax.nn.sigmoid(og)) @ p["w_down"]
    return x + y, (C, n, m, new_cs)


def slstm_forward(x, p, cfg, *, initial_state=None):
    """x (B,S,d) -> (y, state). Fully sequential exp-gated sLSTM."""
    B, S, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    wx = (xn @ p["w_gates"]).astype(jnp.float32)                      # (B,S,4d)

    if initial_state is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.ones((B, d), jnp.float32)
        h0 = jnp.zeros((B, d), jnp.float32)
        m0 = jnp.zeros((B, d), jnp.float32)
    else:
        c0, n0, h0, m0 = initial_state
    R = p["r_gates"].astype(jnp.float32)                              # (4,nh,hd,hd)

    def step(carry, wxt):
        c, n, h, m = carry
        hh = h.reshape(B, nh, hd)
        rec = jnp.einsum("ghij,bhj->gbhi", R, hh).reshape(4, B, d)
        zt = jnp.tanh(wxt[..., :d] + rec[0])
        it = wxt[..., d:2 * d] + rec[1]
        ft = wxt[..., 2 * d:3 * d] + rec[2]
        ot = jax.nn.sigmoid(wxt[..., 3 * d:] + rec[3])
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(it - m_new)
        c = fp * c + ip * zt
        n = fp * n + ip
        h = ot * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    (cf, nf, hf, mf), hs = jax.lax.scan(step, (c0, n0, h0, m0),
                                        jnp.swapaxes(wx, 0, 1))
    h = jnp.swapaxes(hs, 0, 1).astype(x.dtype)
    h = rmsnorm(h, p["gnorm"], cfg.norm_eps)
    ff = p["w_down"].shape[0]
    up = h @ p["w_up"]
    y = (jax.nn.gelu(up[..., :ff]) * up[..., ff:]) @ p["w_down"]
    return x + y, (cf, nf, hf, mf)


def slstm_decode(x, p, cfg, state):
    y, new_state = slstm_forward(x, p, cfg, initial_state=state)
    return y, new_state


def init_mlstm_state(cfg, batch: int, dtype=jnp.float32):
    di, nh, hd = _mlstm_dims(cfg)
    return (jnp.zeros((batch, nh, hd, hd), jnp.float32),
            jnp.zeros((batch, nh, hd), jnp.float32),
            jnp.zeros((batch, nh), jnp.float32),
            jnp.zeros((batch, 3, di), dtype))


def init_slstm_state(cfg, batch: int):
    d = cfg.d_model
    return (jnp.zeros((batch, d), jnp.float32), jnp.ones((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32), jnp.zeros((batch, d), jnp.float32))
