"""Attention substrate.

``flash_prefill`` — chunked online-softmax attention in pure jnp. This is the
dry-run/roofline path: it never materialises the S x S score matrix (the kv
axis is streamed in ``block_k`` chunks exactly like the Pallas kernel's
BlockSpec loop), so compiled ``memory_analysis()`` stays honest at 32k prefill.
The TPU runtime path is ``repro.kernels.flash_attention`` (same blocking).

``decode_attention`` — one-token attention against a dense ring-buffer cache
(B, S, KV, D) with per-request valid lengths and optional sliding window.

``mla_*`` — Multi-Head Latent Attention (DeepSeek-R1): prefill plus the
*absorbed* decode form whose cache is the (kv_rank + rope) latent per token —
the compression the paper credits for R1's capacity advantage (§V-D).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk_mask(q_pos, kv_pos, kv_limit, window: int):
    """valid[b, q, c]: kv visible to q. q_pos (B,Sq) or (1,Sq); kv_pos (C,);
    kv_limit (B,1) exclusive upper bound on valid cache entries."""
    valid = kv_pos[None, None, :] <= q_pos[..., None]               # causal
    valid &= kv_pos[None, None, :] < kv_limit[..., None]
    if window and window > 0:
        valid &= kv_pos[None, None, :] > q_pos[..., None] - window
    return valid


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  q_positions: jax.Array, kv_lens: Optional[jax.Array] = None,
                  window: int = 0, block_k: int = 512,
                  scale: Optional[float] = None) -> jax.Array:
    """q (B,Sq,H,D); k,v (B,Skv,KV,D); H % KV == 0. Returns (B,Sq,H,D).

    q_positions (B,Sq) or (1,Sq) absolute positions (for chunked prefill the
    offset is the tokens already in cache); kv_lens (B,) exclusive valid length
    of k/v (defaults to Skv).
    """
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = scale if scale is not None else D ** -0.5
    block_k = min(block_k, max(Skv, 1))     # never pad beyond the true length
    nchunks = -(-Skv // block_k)
    pad = nchunks * block_k - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if kv_lens is None:
        kv_limit = jnp.full((B, 1), Skv, jnp.int32)
    else:
        kv_limit = kv_lens.astype(jnp.int32).reshape(B, 1)

    qg = (q.astype(jnp.float32) * scale).astype(q.dtype).reshape(B, Sq, KV, g, D)
    qg = jnp.transpose(qg, (0, 2, 3, 1, 4))                         # (B,KV,g,Sq,D)

    def body(carry, ci):
        # named_scope tags these ops in HLO metadata: the roofline analyzer
        # buckets "flash_core" traffic separately because the Pallas runtime
        # kernel keeps scores/stats in VMEM (see analysis/hlo.py SCOPED).
        with jax.named_scope("flash_core"):
            m, l, acc = carry
            start = ci * block_k
            kc = jax.lax.dynamic_slice_in_dim(k, start, block_k, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, block_k, axis=1)
            kc = jnp.transpose(kc, (0, 2, 1, 3))                    # (B,KV,C,D)
            vc = jnp.transpose(vc, (0, 2, 1, 3))
            # bf16 operands, fp32 MXU accumulation — no upcast copies
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qg, kc,
                           preferred_element_type=jnp.float32)
            kv_pos = start + jnp.arange(block_k, dtype=jnp.int32)
            valid = _chunk_mask(q_positions, kv_pos, kv_limit, window)
            s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.where(valid[:, None, None, :, :],
                          jnp.exp(s - m_new[..., None]), 0.0)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, g, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, g, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  jnp.arange(nchunks, dtype=jnp.int32))
    out = jnp.where(l[..., None] > 0, acc / jnp.maximum(l[..., None], 1e-30), 0.0)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lens: jax.Array, *, window: int = 0,
                     scale: Optional[float] = None) -> jax.Array:
    """q (B,1,H,D); caches (B,S,KV,D); lens (B,) = index of the newest token
    (attention covers positions 0..lens inclusive). Returns (B,1,H,D)."""
    B, _, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    scale = scale if scale is not None else D ** -0.5
    qg = (q.astype(jnp.float32) * scale).astype(k_cache.dtype).reshape(B, KV, g, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    valid = pos[None, :] <= lens.astype(jnp.int32)[:, None]
    if window and window > 0:
        valid &= pos[None, :] > lens.astype(jnp.int32)[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


# --------------------------------------------------------------------------- MLA
def mla_prefill(x, p, cfg, positions, kv_lens=None):
    """Multi-Head Latent Attention prefill. Returns (out, (ckv, k_pe)) where the
    returned latents are the decode cache (kv_rank + rope_dim per token)."""
    from repro.models.common import rmsnorm, rope
    ml = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = rmsnorm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    qs = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"])
    q_nope = qs[..., :ml.qk_nope_head_dim]
    q_pe = rope(qs[..., ml.qk_nope_head_dim:], positions, cfg.rope_theta)
    ckv = rmsnorm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)
    k_pe = rope((x @ p["w_kr"])[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    k_nope = jnp.einsum("bsr,rhe->bshe", ckv, p["w_uk"])
    vv = jnp.einsum("bsr,rhe->bshe", ckv, p["w_uv"])
    scale = (ml.qk_nope_head_dim + ml.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bqhe,bkhe->bhqk", q_nope, k_nope)
         + jnp.einsum("bqhe,bke->bhqk", q_pe, k_pe)) * scale
    s = s.astype(jnp.float32)
    qp = positions.reshape(1, S) if positions.ndim == 1 else positions
    kpos = jnp.arange(S, dtype=jnp.int32)
    valid = kpos[None, None, :] <= qp[:, :, None]
    if kv_lens is not None:
        valid &= kpos[None, None, :] < kv_lens.astype(jnp.int32)[:, None, None]
    s = jnp.where(valid[:, None, :, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqk,bkhe->bqhe", w, vv)
    out = jnp.einsum("bqhe,hed->bqd", ctx, p["w_o"])
    return out, (ckv, k_pe)


def mla_decode(x, p, cfg, ckv_cache, kpe_cache, lens):
    """Absorbed MLA decode: the cache is the latent (B,S,rank)+(B,S,rope)."""
    from repro.models.common import rmsnorm, rope
    ml = cfg.mla
    B = x.shape[0]
    pos = lens.astype(jnp.int32)
    cq = rmsnorm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    qs = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"])
    q_nope = qs[..., :ml.qk_nope_head_dim]
    q_pe = rope(qs[..., ml.qk_nope_head_dim:], pos[:, None], cfg.rope_theta)
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, p["w_uk"])          # absorb w_uk
    scale = (ml.qk_nope_head_dim + ml.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bshr,btr->bhst", q_lat, ckv_cache)
         + jnp.einsum("bshe,bte->bhst", q_pe, kpe_cache)) * scale
    s = s.astype(jnp.float32)[:, :, 0, :]                            # (B,H,S)
    t = jnp.arange(ckv_cache.shape[1], dtype=jnp.int32)
    valid = t[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bht,btr->bhr", w, ckv_cache)
    ctx = jnp.einsum("bhr,rhe->bhe", ctx_lat, p["w_uv"])             # absorb w_uv
    out = jnp.einsum("bhe,hed->bd", ctx, p["w_o"])
    return out[:, None, :]
