"""Mamba2 (SSD) blocks — chunked-scan training path + O(1)-state decode.

Projections are stored unpacked (w_z/w_x/w_B/w_C/w_dt) so each piece can carry
its own sharding (d_inner and heads on "model"; the B/C group projections are
replicated — n_groups=1). The inter-chunk recurrence is a lax.scan carrying
(B, nh, hd, ds) states; intra-chunk work is batched einsums, so per-step
memory is O(B * chunk^2 * nh) rather than O(B * S^2).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm


def init_mamba_params(key, cfg, dtype=jnp.float32) -> Dict[str, jax.Array]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    nh = di // s.head_dim
    ds = s.d_state
    ks = jax.random.split(key, 9)
    return {
        "norm": jnp.ones((d,), dtype),
        "w_z": dense_init(ks[0], (d, di), d, dtype),
        "w_x": dense_init(ks[1], (d, di), d, dtype),
        "w_B": dense_init(ks[2], (d, ds), d, dtype),
        "w_C": dense_init(ks[3], (d, ds), d, dtype),
        "w_dt": dense_init(ks[4], (d, nh), d, dtype),
        "conv_x": dense_init(ks[5], (s.conv_width, di), s.conv_width, dtype),
        "conv_B": dense_init(ks[6], (s.conv_width, ds), s.conv_width, dtype),
        "conv_C": dense_init(ks[7], (s.conv_width, ds), s.conv_width, dtype),
        "A_log": jnp.zeros((nh,), dtype),          # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), dtype),
        "dt_bias": jnp.full((nh,), -2.0, dtype),   # softplus(-2) ~ 0.13
        "gnorm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[8], (di, d), di, dtype),
    }


MAMBA_AXES = {
    "norm": ("embed",), "w_z": ("embed", "ssm_inner"), "w_x": ("embed", "ssm_inner"),
    "w_B": ("embed", None), "w_C": ("embed", None), "w_dt": ("embed", "ssm_heads"),
    "conv_x": (None, "ssm_inner"), "conv_B": (None, None), "conv_C": (None, None),
    "A_log": ("ssm_heads",), "D": ("ssm_heads",), "dt_bias": ("ssm_heads",),
    "gnorm": ("ssm_inner",), "out_proj": ("ssm_inner", "embed"),
}


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x (B,S,C); w (cw,C); state (B,cw-1,C) or None.
    Returns (out (B,S,C), new_state (B,cw-1,C))."""
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(cw))
    return out, xp[:, -(cw - 1):, :] if cw > 1 else state


def mamba2_forward(x, p, cfg, *, initial_state=None, conv_state=None):
    """x (B,S,d) -> (y (B,S,d), (ssm_state, conv_states))."""
    s = cfg.ssm
    B, S, d = x.shape
    di = s.expand * d
    nh = di // s.head_dim
    hd, ds = s.head_dim, s.d_state
    Q = min(s.chunk, S)
    nchunks, rem = divmod(S, Q)

    z = x @ p["w_z"]
    xr = x @ p["w_x"]
    Bc = x @ p["w_B"]
    Cc = x @ p["w_C"]
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))          # (B,S,nh)
    cs_x = conv_state[0] if conv_state is not None else None
    cs_B = conv_state[1] if conv_state is not None else None
    cs_C = conv_state[2] if conv_state is not None else None
    xr, ns_x = _causal_conv(xr, p["conv_x"], cs_x)
    Bc, ns_B = _causal_conv(Bc, p["conv_B"], cs_B)
    Cc, ns_C = _causal_conv(Cc, p["conv_C"], cs_C)
    xr, Bc, Cc = jax.nn.silu(xr), jax.nn.silu(Bc), jax.nn.silu(Cc)

    xh = xr.reshape(B, S, nh, hd).astype(jnp.float32)
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                      # (nh,)

    h0 = initial_state if initial_state is not None \
        else jnp.zeros((B, nh, hd, ds), jnp.float32)

    def chunk_body(h, inp):
        xq, dtq, Bq, Cq = inp                   # (B,Q,nh,hd),(B,Q,nh),(B,Q,ds)
        q = xq.shape[1]
        a = dtq * A                              # (B,q,nh) log-decay, <= 0
        cum = jnp.cumsum(a, axis=1)
        # intra-chunk (masked decayed scores, shared B/C group)
        CB = jnp.einsum("bqn,bpn->bqp", Cq, Bq)                       # (B,q,q)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])      # (B,q,q,nh)
        tril = jnp.tril(jnp.ones((q, q), bool))
        decay = jnp.where(tril[None, :, :, None], decay, 0.0)
        y_intra = jnp.einsum("bqp,bqph,bph,bphd->bqhd",
                             CB, decay, dtq, xq)
        # contribution of the carried state
        y_state = jnp.einsum("bqn,bhdn->bqhd", Cq, h) * jnp.exp(cum)[..., None]
        # next state
        w_in = jnp.exp(cum[:, -1:, :] - cum) * dtq                    # (B,q,nh)
        h_new = jnp.exp(cum[:, -1, :])[:, :, None, None] * h \
            + jnp.einsum("bqh,bqhd,bqn->bhdn", w_in, xq, Bq)
        return h_new, y_intra + y_state

    # full chunks via scan, remainder (S % Q) as one extra chunk_body call
    def to_chunks(a):
        return a[:, :nchunks * Q].reshape(B, nchunks, Q, *a.shape[2:]).swapaxes(0, 1)

    if nchunks:
        xs = tuple(map(to_chunks, (xh, dt, Bf, Cf)))
        h_last, y_c = jax.lax.scan(chunk_body, h0, xs)
        y = y_c.swapaxes(0, 1).reshape(B, nchunks * Q, nh, hd)
    else:
        h_last, y = h0, jnp.zeros((B, 0, nh, hd), jnp.float32)
    if rem:
        tail = tuple(a[:, nchunks * Q:] for a in (xh, dt, Bf, Cf))
        h_last, y_tail = chunk_body(h_last, tail)
        y = jnp.concatenate([y, y_tail], axis=1)
    y = y.reshape(B, S, nh, hd)
    y = y + p["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    return y @ p["out_proj"], (h_last, (ns_x, ns_B, ns_C))


def mamba2_decode(x, p, cfg, state):
    """One-token step. x (B,1,d); state = (h (B,nh,hd,ds), conv_states)."""
    s = cfg.ssm
    B, _, d = x.shape
    di = s.expand * d
    nh = di // s.head_dim
    hd, ds = s.head_dim, s.d_state
    h, (cs_x, cs_B, cs_C) = state

    z = x @ p["w_z"]
    xr = x @ p["w_x"]
    Bc = x @ p["w_B"]
    Cc = x @ p["w_C"]
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]    # (B,nh)
    xr, ns_x = _causal_conv(xr, p["conv_x"], cs_x)
    Bc, ns_B = _causal_conv(Bc, p["conv_B"], cs_B)
    Cc, ns_C = _causal_conv(Cc, p["conv_C"], cs_C)
    xr, Bc, Cc = jax.nn.silu(xr), jax.nn.silu(Bc), jax.nn.silu(Cc)

    xh = xr.reshape(B, nh, hd).astype(jnp.float32)
    Bf = Bc[:, 0].astype(jnp.float32)                                 # (B,ds)
    Cf = Cc[:, 0].astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                           # (B,nh)
    h_new = decay[:, :, None, None] * h \
        + jnp.einsum("bh,bhd,bn->bhdn", dt, xh, Bf)
    y = jnp.einsum("bn,bhdn->bhd", Cf, h_new)
    y = y + p["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    return y @ p["out_proj"], (h_new, (ns_x, ns_B, ns_C))


def init_mamba_state(cfg, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    h = jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32)
    cs = (jnp.zeros((batch, s.conv_width - 1, di), dtype),
          jnp.zeros((batch, s.conv_width - 1, s.d_state), dtype),
          jnp.zeros((batch, s.conv_width - 1, s.d_state), dtype))
    return h, cs
