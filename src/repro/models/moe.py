"""Token-choice top-k Mixture-of-Experts with shard_map expert parallelism.

Dispatch is *sort-based* (MegaBlocks-style): assignments are sorted by expert,
positions within each expert come from an exclusive-cumsum histogram, and
tokens are scattered into capacity-bounded (E, C, d) buffers. No (T, E, C)
one-hot tensors exist anywhere, so the dry-run memory analysis stays sane at
kimi-k2 scale (384 experts, 1M batch-tokens).

Two distribution modes (DESIGN.md §5):
  * ``split``      — tokens sharded over the model axis too; all_to_all moves
                     token buffers to their expert-owner shard and back.
                     Used when seq (or batch*seq) divides the model axis
                     (train / prefill).
  * ``replicated`` — tokens replicated over the model axis (decode: one token
                     per sequence); every shard computes its own experts'
                     contribution locally and a psum over the model axis
                     combines. Zero dispatch traffic.

Expert weights are stacked (E, d, f) with E sharded over "model" (EP) and d
over "data" (FSDP); the FSDP gather is an explicit all_gather inside the
shard_map body.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import ParallelContext, shard_map


def router_probs(x, w_router):
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1)


def _topk_assignments(probs, top_k: int):
    w, idx = jax.lax.top_k(probs, top_k)                    # (T,k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, idx


def _dispatch_indices(flat_expert: jax.Array, n_experts: int, capacity: int):
    """Sort-based dispatch. flat_expert (A,) -> (slot (A,), keep (A,), order)."""
    A = flat_expert.shape[0]
    order = jnp.argsort(flat_expert, stable=True)
    sorted_e = flat_expert[order]
    counts = jnp.bincount(flat_expert, length=n_experts)
    starts = jnp.cumsum(counts) - counts                    # exclusive cumsum
    pos_in_e = jnp.arange(A, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep_sorted = pos_in_e < capacity
    # dropped assignments get an out-of-range slot so scatter(mode="drop")
    # discards them instead of colliding with a kept token's slot
    slot_sorted = jnp.where(keep_sorted,
                            sorted_e.astype(jnp.int32) * capacity + pos_in_e,
                            n_experts * capacity)
    inv = jnp.argsort(order, stable=True)                   # back to assignment order
    return slot_sorted[inv], keep_sorted[inv]


def _expert_ffn(buf, wg, wu, wd):
    """buf (E, C, d); weights (E, d, f)/(E, f, d)."""
    h = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(h) * u
    return jnp.einsum("ecf,efd->ecd", h, wd)


def moe_ffn_reference(x, params, cfg) -> jax.Array:
    """Single-device oracle: identical math (incl. capacity drops), no mesh.
    x (T, d) -> (T, d)."""
    m = cfg.moe
    T, d = x.shape
    probs = router_probs(x, params["router"])
    w, idx = _topk_assignments(probs, m.top_k)
    A = T * m.top_k
    capacity = max(1, int(m.capacity_factor * A / m.n_experts))
    flat_e = idx.reshape(A)
    flat_w = w.reshape(A)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), m.top_k)
    slot, keep = _dispatch_indices(flat_e, m.n_experts, capacity)
    buf = jnp.zeros((m.n_experts * capacity, d), x.dtype)
    buf = buf.at[slot].set(x[tok] * keep[:, None].astype(x.dtype), mode="drop")
    out_buf = _expert_ffn(buf.reshape(m.n_experts, capacity, d),
                          params["we_gate"], params["we_up"], params["we_down"])
    gathered = out_buf.reshape(-1, d)[slot]
    contrib = gathered * (flat_w[:, None] * keep[:, None]).astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[tok].add(contrib)
    if m.n_shared_experts:
        out = out + _shared_ffn(x, params)
    return out


def _shared_ffn(x, params):
    h = jax.nn.silu(x @ params["ws_gate"]) * (x @ params["ws_up"])
    return h @ params["ws_down"]


def moe_ffn(x, params, cfg, ctx: ParallelContext, *, token_axes) -> jax.Array:
    """Distributed MoE FFN. x (..., d) flattened internally to (T, d).

    token_axes: PartitionSpec entry for the token dim of the *flattened* input
    (e.g. ("pod","data")). Chooses split vs replicated dispatch by divisibility.
    """
    if ctx.mesh is None or ctx.mesh.size == 1:
        shape = x.shape
        return moe_ffn_reference(x.reshape(-1, shape[-1]), params, cfg).reshape(shape)

    m = cfg.moe
    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)
    T = xt.shape[0]
    tp = ctx.tp
    dp = ctx.dp
    mode = ctx.moe_dispatch
    if mode == "auto":
        mode = "split" if (T % (dp * tp) == 0 and T // (dp * tp) > 0) else "replicated"

    e_loc = m.n_experts // tp
    mesh = ctx.mesh
    maxis = ctx.model_axis
    faxis = ctx.fsdp_axis

    wspec_in = P(None, faxis, None)     # (E_loc, d/f, f) before gather
    if mode == "split":
        t_loc = T // (dp * tp)
        cap = max(1, int(m.capacity_factor * t_loc * m.top_k / m.n_experts))

        def body(xt_l, router, wg, wu, wd, sg, su, sd):
            # xt_l (t_loc, d) ; router (d, E) ; wg/wu (E_loc, d, f) ; wd (E_loc, f, d)
            if faxis is not None:
                wg = jax.lax.all_gather(wg, faxis, axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, faxis, axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, faxis, axis=2, tiled=True)
            probs = router_probs(xt_l, router)
            w, idx = _topk_assignments(probs, m.top_k)
            A = t_loc * m.top_k
            flat_e = idx.reshape(A)
            flat_w = w.reshape(A)
            tok = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), m.top_k)
            slot, keep = _dispatch_indices(flat_e, m.n_experts, cap)
            send = jnp.zeros((m.n_experts * cap, d), xt_l.dtype)
            send = send.at[slot].set(xt_l[tok] * keep[:, None].astype(xt_l.dtype),
                                     mode="drop")
            send = send.reshape(tp, e_loc * cap, d)
            recv = jax.lax.all_to_all(send, maxis, split_axis=0, concat_axis=0,
                                      tiled=False)          # (tp, e_loc*cap, d)
            # recv[p] = tokens from peer p destined to my experts, laid out
            # (e_loc, cap, d). Stack peers on the capacity axis:
            buf = recv.reshape(tp, e_loc, cap, d).transpose(1, 0, 2, 3) \
                      .reshape(e_loc, tp * cap, d)
            out_buf = _expert_ffn(buf, wg, wu, wd)           # (e_loc, tp*cap, d)
            back = out_buf.reshape(e_loc, tp, cap, d).transpose(1, 0, 2, 3)
            back = jax.lax.all_to_all(back, maxis, split_axis=0, concat_axis=0,
                                      tiled=False)           # (tp, e_loc, cap, d)
            out_flat = back.reshape(m.n_experts * cap, d)
            gathered = out_flat[slot]
            contrib = gathered * (flat_w[:, None] * keep[:, None]).astype(xt_l.dtype)
            out = jnp.zeros((t_loc, d), xt_l.dtype).at[tok].add(contrib)
            if m.n_shared_experts:
                if faxis is not None:
                    sg = jax.lax.all_gather(sg, faxis, axis=0, tiled=True)
                    su = jax.lax.all_gather(su, faxis, axis=0, tiled=True)
                    sd = jax.lax.all_gather(sd, faxis, axis=1, tiled=True)
                out = out + (jax.nn.silu(xt_l @ sg) * (xt_l @ su)) @ sd
            return out

        tok_spec = P((*(ctx.batch_axes), maxis))
        shared_specs = (P(faxis, None), P(faxis, None), P(None, faxis)) \
            if m.n_shared_experts else (P(), P(), P())
        sh = params.get("ws_gate", jnp.zeros((), x.dtype))
        su_ = params.get("ws_up", jnp.zeros((), x.dtype))
        sd_ = params.get("ws_down", jnp.zeros((), x.dtype))
        out = shard_map(
            body, mesh=mesh,
            in_specs=(P((*(ctx.batch_axes), maxis)), P(None, None),
                      P(maxis, faxis, None), P(maxis, faxis, None),
                      P(maxis, None, faxis), *shared_specs),
            out_specs=tok_spec, check=False,
        )(xt, params["router"], params["we_gate"], params["we_up"],
          params["we_down"], sh, su_, sd_)
        return out.reshape(shape)

    # mode == "replicated": tokens replicated over model axis; each shard runs
    # its local experts on every token, psum combines. (decode path)
    t_loc = T // dp
    cap = max(1, int(m.capacity_factor * t_loc * m.top_k / max(e_loc, 1)))
    ff_shard = ctx.moe_ff_shard and faxis is not None

    def body_rep(xt_l, router, wg, wu, wd, sg, su, sd):
        if faxis is not None and not ff_shard:
            wg = jax.lax.all_gather(wg, faxis, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, faxis, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, faxis, axis=2, tiled=True)
        probs = router_probs(xt_l, router)
        w, idx = _topk_assignments(probs, m.top_k)
        A = t_loc * m.top_k
        flat_e = idx.reshape(A)
        flat_w = w.reshape(A)
        tok = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), m.top_k)
        my = jax.lax.axis_index(maxis)
        # keep only assignments owned by this shard's experts
        local = (flat_e >= my * e_loc) & (flat_e < (my + 1) * e_loc)
        local_e = jnp.where(local, flat_e - my * e_loc, 0)
        slot, keep = _dispatch_indices(
            jnp.where(local, local_e, e_loc).astype(jnp.int32), e_loc + 1, cap)
        keep = keep & local
        buf = jnp.zeros(((e_loc + 1) * cap, d), xt_l.dtype)
        buf = buf.at[slot].set(xt_l[tok] * keep[:, None].astype(xt_l.dtype),
                               mode="drop")
        out_buf = _expert_ffn(buf.reshape(e_loc + 1, cap, d)[:e_loc], wg, wu, wd)
        if ff_shard:
            # §Perf: expert d_ff sharded over the fsdp axis — the down-proj
            # is a partial sum; a small activation psum replaces the per-step
            # expert weight all-gather
            out_buf = jax.lax.psum(out_buf, faxis)
        gathered = jnp.concatenate([out_buf.reshape(-1, d),
                                    jnp.zeros((cap, d), xt_l.dtype)])[slot]
        contrib = gathered * (flat_w[:, None] * keep[:, None]).astype(xt_l.dtype)
        out = jnp.zeros((t_loc, d), xt_l.dtype).at[tok].add(contrib)
        out = jax.lax.psum(out, maxis)
        if m.n_shared_experts:
            if ff_shard:
                out = out + jax.lax.psum(
                    (jax.nn.silu(xt_l @ sg) * (xt_l @ su)) @ sd, faxis)
            else:
                if faxis is not None:
                    sg = jax.lax.all_gather(sg, faxis, axis=0, tiled=True)
                    su = jax.lax.all_gather(su, faxis, axis=0, tiled=True)
                    sd = jax.lax.all_gather(sd, faxis, axis=1, tiled=True)
                out = out + (jax.nn.silu(xt_l @ sg) * (xt_l @ su)) @ sd
        return out

    tok_spec = P((*(ctx.batch_axes),))
    if ff_shard:
        wspecs = (P(maxis, None, faxis), P(maxis, None, faxis),
                  P(maxis, faxis, None))
        shared_specs = (P(None, faxis), P(None, faxis), P(faxis, None)) \
            if m.n_shared_experts else (P(), P(), P())
    else:
        wspecs = (P(maxis, faxis, None), P(maxis, faxis, None),
                  P(maxis, None, faxis))
        shared_specs = (P(faxis, None), P(faxis, None), P(None, faxis)) \
            if m.n_shared_experts else (P(), P(), P())
    sh = params.get("ws_gate", jnp.zeros((), x.dtype))
    su_ = params.get("ws_up", jnp.zeros((), x.dtype))
    sd_ = params.get("ws_down", jnp.zeros((), x.dtype))
    out = shard_map(
        body_rep, mesh=mesh,
        in_specs=(tok_spec, P(None, None), *wspecs, *shared_specs),
        out_specs=tok_spec, check=False,
    )(xt, params["router"], params["we_gate"], params["we_up"],
      params["we_down"], sh, su_, sd_)
    return out.reshape(shape)
