"""Composable decoder stack for every assigned architecture family.

Parameter trees are built from ``ParamSpec`` leaves (shape + logical axes +
init), so a single definition yields real initialisation (tests/examples),
abstract ShapeDtypeStructs (dry-run lowering — never allocated), and
NamedShardings (via ParallelContext rules).

Layout modes (DESIGN.md §5):
  * ``train`` — q heads padded to the model-axis multiple and laid out
    *g-major* (reshape (hp,) -> (g, KV) keeps the sharded axis divisible);
    kv projections keep their TRUE head count (replicated over the model
    axis) so tied-replica gradients never diverge.
  * ``serve`` — kv heads tiled to kvp (exact replicas) and laid out
    *kv-major*; the KV cache stores kvp heads sharded over "model".

Homogeneous layer stacks are scanned (single-layer HLO); MoE dense-prefix
layers, zamba2 shared-attention groups and xLSTM 7:1 groups are scanned over
their own homogeneous stacks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.common import dense_init, rmsnorm, rope, softmax_xent
from repro.parallel.sharding import (ParallelContext, kv_to_orig, padded_heads,
                                     q_to_orig)


# ============================================================== param specs
@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones
    fan_in: int = 1

    def abstract(self, dtype):
        return jax.ShapeDtypeStruct(self.shape, dtype)


def _is_spec(x):
    return isinstance(x, ParamSpec)


def spec_tree_map(f, tree):
    return jax.tree_util.tree_map(f, tree, is_leaf=_is_spec)


def _stackable(spec: ParamSpec, n: int) -> ParamSpec:
    return ParamSpec((n, *spec.shape), ("layers", *spec.axes), spec.init, spec.fan_in)


def heads_layout(cfg: ModelConfig, ctx: ParallelContext, mode: str):
    """Return (hp, kvx) for a mode: serve pads+tiles kv, train keeps true kv
    unless MHA-alignment forces zero-padded kv. With seq-sharded decode
    (§Perf) the serve cache is unpadded too — kv heads replicate and the
    sequence axis carries the model-parallel split instead."""
    tp = ctx.tp
    hp, kvp = padded_heads(cfg.n_heads, cfg.n_kv_heads, tp)
    if mode == "serve":
        if ctx.seq_shard_decode:
            kvt = cfg.n_kv_heads if hp % cfg.n_kv_heads == 0 else kvp
            return hp, kvt
        return hp, kvp
    kvt = cfg.n_kv_heads if hp % cfg.n_kv_heads == 0 else kvp
    return hp, kvt


def _attn_specs(cfg: ModelConfig, ctx: ParallelContext, mode: str) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    if cfg.attention == "mla":
        ml = cfg.mla
        qk = ml.qk_nope_head_dim + ml.qk_rope_head_dim
        s = {
            "w_dq": ParamSpec((d, ml.q_lora_rank), ("embed", None), fan_in=d),
            "q_norm": ParamSpec((ml.q_lora_rank,), (None,), "ones"),
            "w_uq": ParamSpec((ml.q_lora_rank, cfg.n_heads, qk),
                              (None, "heads", None), fan_in=ml.q_lora_rank),
            "w_dkv": ParamSpec((d, ml.kv_lora_rank), ("embed", None), fan_in=d),
            "kv_norm": ParamSpec((ml.kv_lora_rank,), (None,), "ones"),
            "w_kr": ParamSpec((d, ml.qk_rope_head_dim), ("embed", None), fan_in=d),
            "w_uk": ParamSpec((ml.kv_lora_rank, cfg.n_heads, ml.qk_nope_head_dim),
                              (None, "heads", None), fan_in=ml.kv_lora_rank),
            "w_uv": ParamSpec((ml.kv_lora_rank, cfg.n_heads, ml.v_head_dim),
                              (None, "heads", None), fan_in=ml.kv_lora_rank),
            "w_o": ParamSpec((cfg.n_heads, ml.v_head_dim, d),
                             ("heads", None, "embed"), fan_in=cfg.n_heads * ml.v_head_dim),
            "attn_norm": ParamSpec((d,), (None,), "ones"),
        }
        return s
    hp, kvx = heads_layout(cfg, ctx, mode)
    kv_axis = "kv_heads" if (kvx != cfg.n_kv_heads
                             or (mode == "serve" and not ctx.seq_shard_decode)) \
        else "kv_heads_exact"
    # train_kv_2d: unpadded kv projections shard d_model over BOTH mesh axes
    # (2D contracting shard, partial+psum) instead of replicating the kv
    # compute across "model" — a §Perf lever for the train layout
    kv_in = "embed_kv" if (mode == "train" and kv_axis == "kv_heads_exact") \
        else "embed"
    s = {
        "attn_norm": ParamSpec((d,), (None,), "ones"),
        "wq": ParamSpec((d, hp, hd), ("embed", "heads", None), fan_in=d),
        "wk": ParamSpec((d, kvx, hd), (kv_in, kv_axis, None), fan_in=d),
        "wv": ParamSpec((d, kvx, hd), (kv_in, kv_axis, None), fan_in=d),
        "wo": ParamSpec((hp, hd, d), ("heads", None, "embed"), fan_in=hp * hd),
    }
    if cfg.qk_norm:
        s["qn"] = ParamSpec((hd,), (None,), "ones")
        s["kn"] = ParamSpec((hd,), (None,), "ones")
    return s


def _mlp_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mlp_norm": ParamSpec((d,), (None,), "ones"),
        "w_gate": ParamSpec((d, f), ("embed", "mlp"), fan_in=d),
        "w_up": ParamSpec((d, f), ("embed", "mlp"), fan_in=d),
        "w_down": ParamSpec((f, d), ("mlp", "embed"), fan_in=f),
    }


def _moe_specs(cfg: ModelConfig, ctx: ParallelContext) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    m = cfg.moe
    fe = m.d_ff_expert
    if ctx.moe_ff_shard:
        # §Perf: shard the expert d_ff over the fsdp axis instead of d_model
        # (no per-step expert weight gathers; tiny activation psum instead)
        up_axes = ("expert", None, "expert_ff")
        down_axes = ("expert", "expert_ff", None)
        sg_axes, sd_axes = (None, "expert_ff"), ("expert_ff", None)
    else:
        up_axes = ("expert", "expert_in", None)
        down_axes = ("expert", None, "expert_in")
        sg_axes, sd_axes = ("embed", None), (None, "embed")
    s = {
        "mlp_norm": ParamSpec((d,), (None,), "ones"),
        "router": ParamSpec((d, m.n_experts), (None, None), fan_in=d),
        "we_gate": ParamSpec((m.n_experts, d, fe), up_axes, fan_in=d),
        "we_up": ParamSpec((m.n_experts, d, fe), up_axes, fan_in=d),
        "we_down": ParamSpec((m.n_experts, fe, d), down_axes, fan_in=fe),
    }
    if m.n_shared_experts:
        fs = fe * m.n_shared_experts
        s["ws_gate"] = ParamSpec((d, fs), sg_axes, fan_in=d)
        s["ws_up"] = ParamSpec((d, fs), sg_axes, fan_in=d)
        s["ws_down"] = ParamSpec((fs, d), sd_axes, fan_in=fs)
    return s


def _mamba_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    nh = di // s.head_dim
    ds = s.d_state
    cw = s.conv_width
    ax = ssm_lib.MAMBA_AXES
    shapes = {
        "norm": ((d,), "ones"), "w_z": ((d, di), "normal"), "w_x": ((d, di), "normal"),
        "w_B": ((d, ds), "normal"), "w_C": ((d, ds), "normal"),
        "w_dt": ((d, nh), "normal"),
        "conv_x": ((cw, di), "normal"), "conv_B": ((cw, ds), "normal"),
        "conv_C": ((cw, ds), "normal"),
        "A_log": ((nh,), "zeros"), "D": ((nh,), "ones"), "dt_bias": ((nh,), "zeros"),
        "gnorm": ((di,), "ones"), "out_proj": ((di, d), "normal"),
    }
    fan = {"w_z": d, "w_x": d, "w_B": d, "w_C": d, "w_dt": d,
           "conv_x": cw, "conv_B": cw, "conv_C": cw, "out_proj": di}
    return {k: ParamSpec(sh, ax[k], init, fan.get(k, 1))
            for k, (sh, init) in shapes.items()}


def _mlstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    di = 2 * d
    nh = cfg.n_heads
    ax = xlstm_lib.MLSTM_AXES
    shapes = {
        "norm": ((d,), "ones"), "w_up": ((d, 2 * di), "normal"),
        "conv": ((4, di), "normal"),
        "w_q": ((di, di), "normal"), "w_k": ((di, di), "normal"),
        "w_v": ((di, di), "normal"), "w_if": ((di, 2 * nh), "normal"),
        "gnorm": ((di,), "ones"), "w_down": ((di, d), "normal"),
        "skip": ((di, di), "normal"),
    }
    fan = {"w_up": d, "conv": 4, "w_q": di, "w_k": di, "w_v": di,
           "w_if": di, "w_down": di, "skip": di}
    return {k: ParamSpec(sh, ax[k], init, fan.get(k, 1))
            for k, (sh, init) in shapes.items()}


def _slstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    ff = int(round(4 * d / 3 / 64)) * 64 or 64
    ax = xlstm_lib.SLSTM_AXES
    shapes = {
        "norm": ((d,), "ones"), "w_gates": ((d, 4 * d), "normal"),
        "r_gates": ((4, nh, hd, hd), "normal"),
        "gnorm": ((d,), "ones"), "w_up": ((d, 2 * ff), "normal"),
        "w_down": ((ff, d), "normal"),
    }
    fan = {"w_gates": d, "r_gates": hd, "w_up": d, "w_down": ff}
    return {k: ParamSpec(sh, ax[k], init, fan.get(k, 1))
            for k, (sh, init) in shapes.items()}


def slstm_ff(cfg: ModelConfig) -> int:
    return int(round(4 * cfg.d_model / 3 / 64)) * 64 or 64


def build_param_specs(cfg: ModelConfig, ctx: ParallelContext, mode: str = "train"):
    d, v = cfg.d_model, cfg.vocab
    tree: Dict[str, Any] = {"final_norm": ParamSpec((d,), (None,), "ones")}
    if cfg.tie_embeddings:
        tree["embed"] = ParamSpec((v, d), ("vocab", None), fan_in=d)
    else:
        tree["embed"] = ParamSpec((v, d), (None, "d_tp"), fan_in=d)
        tree["lm_head"] = ParamSpec((d, v), ("embed", "vocab"), fan_in=d)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        layer = {**_attn_specs(cfg, ctx, mode)}
        if cfg.moe is not None and cfg.moe.n_experts:
            nd = cfg.moe.first_dense_layers
            nm = cfg.n_layers - nd
            moe_layer = {**layer, **_moe_specs(cfg, ctx)}
            tree["moe_stack"] = {k: _stackable(s, nm) for k, s in moe_layer.items()}
            if nd:
                dense_layer = {**layer, **_mlp_specs(cfg)}
                tree["dense_stack"] = {k: _stackable(s, nd) for k, s in dense_layer.items()}
        else:
            dense_layer = {**layer, **_mlp_specs(cfg)}
            tree["dense_stack"] = {k: _stackable(s, cfg.n_layers)
                                   for k, s in dense_layer.items()}
    elif cfg.family == "hybrid":
        tree["mamba_stack"] = {k: _stackable(s, cfg.n_layers)
                               for k, s in _mamba_specs(cfg).items()}
        tree["shared_attn"] = {**_attn_specs(cfg, ctx, mode), **_mlp_specs(cfg)}
    elif cfg.family == "ssm":
        assert cfg.slstm_every > 0
        groups = cfg.n_layers // cfg.slstm_every
        per = cfg.slstm_every - 1
        tree["mlstm_stack"] = {
            k: ParamSpec((groups, per, *s.shape), ("layers", "layers", *s.axes),
                         s.init, s.fan_in)
            for k, s in _mlstm_specs(cfg).items()}
        tree["slstm_stack"] = {k: _stackable(s, groups)
                               for k, s in _slstm_specs(cfg).items()}
    else:
        raise ValueError(cfg.family)
    return tree


def init_params(cfg: ModelConfig, key, ctx: ParallelContext, mode: str = "train",
                dtype=jnp.float32):
    specs = build_param_specs(cfg, ctx, mode)
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))

    def make(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        return dense_init(k, spec.shape, max(spec.fan_in, 1), dtype)

    vals = [make(s, k) for s, k in zip(leaves, keys)]
    params = jax.tree_util.tree_unflatten(treedef, vals)
    return _postprocess_init(params, cfg, ctx, mode)


def _postprocess_init(params, cfg, ctx, mode):
    """Zero the padded q-head slots (and tile kv replicas in serve mode) so
    padding is mathematically inert."""
    hp, kvx = (None, None)
    if cfg.attention in ("full", "swa") and cfg.family != "ssm":
        hp, kvx = heads_layout(cfg, ctx, mode)
        qmap = _q_slot_to_orig(cfg, ctx, mode)
        kvmap = kv_to_orig(kvx, cfg.n_heads, cfg.n_kv_heads) if kvx != cfg.n_kv_heads \
            else np.arange(kvx)

        def fix_stack(stack):
            if "wq" not in stack:
                return stack
            qmask = jnp.asarray(qmap >= 0, stack["wq"].dtype)
            km = jnp.asarray(np.maximum(kvmap, 0), jnp.int32)
            kmask = jnp.asarray(kvmap >= 0, stack["wk"].dtype)
            out = dict(stack)
            out["wq"] = stack["wq"] * _bmask(qmask, stack["wq"].ndim, -2)
            out["wo"] = stack["wo"] * _bmask(qmask, stack["wo"].ndim, -3)
            if kvx != cfg.n_kv_heads:
                out["wk"] = jnp.take(stack["wk"], km, axis=-2) * _bmask(kmask, stack["wk"].ndim, -2)
                out["wv"] = jnp.take(stack["wv"], km, axis=-2) * _bmask(kmask, stack["wv"].ndim, -2)
            return out

        for name in ("dense_stack", "moe_stack", "shared_attn"):
            if name in params:
                params[name] = fix_stack(params[name])
    return params


def _bmask(mask, ndim, axis):
    """Broadcast a 1-D mask to `ndim` dims placing it at `axis` (negative)."""
    shape = [1] * ndim
    shape[axis] = mask.shape[0]
    return mask.reshape(shape)


def _q_slot_to_orig(cfg, ctx, mode) -> np.ndarray:
    hp, kvx = heads_layout(cfg, ctx, mode)
    if mode == "serve":
        return q_to_orig(hp, kvx, cfg.n_heads, cfg.n_kv_heads)
    # train: g-major layout — slot (j, k) = j*KV + k holds orig head k*g + j
    out = -np.ones(hp, dtype=np.int64)
    g = cfg.n_heads // cfg.n_kv_heads if kvx == cfg.n_kv_heads else 1
    if kvx == cfg.n_kv_heads:
        for k in range(cfg.n_kv_heads):
            for j in range(g):
                out[j * cfg.n_kv_heads + k] = k * g + j
    else:  # MHA zero-padded: identity
        out[:cfg.n_heads] = np.arange(cfg.n_heads)
    return out


def abstract_params(cfg, ctx, mode="train", dtype=jnp.bfloat16):
    specs = build_param_specs(cfg, ctx, mode)
    return spec_tree_map(lambda s: s.abstract(dtype), specs)


def param_shardings(cfg, ctx: ParallelContext, mode="train"):
    specs = build_param_specs(cfg, ctx, mode)
    assert ctx.mesh is not None
    return spec_tree_map(
        lambda s: NamedSharding(ctx.mesh, ctx.spec(*s.axes)), specs)


def param_pspecs(cfg, ctx: ParallelContext, mode="train"):
    specs = build_param_specs(cfg, ctx, mode)
    return spec_tree_map(lambda s: ctx.spec(*s.axes), specs)


# ============================================================== forward
def _gqa_layout(cfg, ctx, mode):
    """(hp, kvx, layout): layout for flash GQA grouping."""
    hp, kvx = heads_layout(cfg, ctx, mode)
    return hp, kvx, ("g_major" if mode == "train" else "kv_major")


def _attn_qkv(x, p, cfg, positions, ctx=None):
    """Project+rope. Returns q (B,S,hp,hd), k,v (B,S,kvx,hd)."""
    h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    if ctx is not None and ctx.serve_2d_tp and h.shape[1] == 1:
        # contract-dim TP (Pope et al. 2D layouts), DECODE-ONLY: the tiny
        # (B,1,d) activation co-shards d with the weights' FSDP shard ->
        # GSPMD emits partial matmul + small psum instead of per-step weight
        # all-gathers. At prefill widths the per-layer activation reshard
        # would dwarf the gathers (measured 5x regression — EXPERIMENTS §Perf).
        h = ctx.shard(h, None, None, "act_d")
    q = jnp.einsum("bsd,dhe->bshe", h, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", h, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", h, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["qn"], cfg.norm_eps)
        k = rmsnorm(k, p["kn"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _flash_gqa(q, k, v, layout, **kw):
    """flash_prefill with either head layout. q (B,S,hp,hd), k (B,S,kvx,hd)."""
    B, S, hp, hd = q.shape
    kvx = k.shape[2]
    if layout == "g_major" and kvx > 1:
        g = hp // kvx
        # (B,S,g,kvx,hd) -> kv-major (B,S,kvx,g,hd) without resharding issues:
        qr = q.reshape(B, S, g, kvx, hd).swapaxes(2, 3).reshape(B, S, hp, hd)
        out = attn.flash_prefill(qr, k, v, **kw)
        return out.reshape(B, S, kvx, g, hd).swapaxes(2, 3).reshape(B, S, hp, hd)
    return attn.flash_prefill(q, k, v, **kw)


def _decode_gqa(q, kc, vc, lens, layout, **kw):
    B, _, hp, hd = q.shape
    kvx = kc.shape[2]
    if layout == "g_major" and kvx > 1:
        g = hp // kvx
        qr = q.reshape(B, 1, g, kvx, hd).swapaxes(2, 3).reshape(B, 1, hp, hd)
        out = attn.decode_attention(qr, kc, vc, lens, **kw)
        return out.reshape(B, 1, kvx, g, hd).swapaxes(2, 3).reshape(B, 1, hp, hd)
    return attn.decode_attention(q, kc, vc, lens, **kw)


def _mlp(x, p, cfg, ctx, token_axes=None):
    h = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    if "router" in p:
        return moe_lib.moe_ffn(h, p, cfg, ctx, token_axes=token_axes)
    if ctx.serve_2d_tp and h.shape[1] == 1:
        h = ctx.shard(h, None, None, "act_d")
    g = jnp.einsum("bsd,df->bsf", h, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", h, p["w_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_down"])


def _attn_mlp_layer_fwd(x, p, cfg, ctx, positions, mode, *, window,
                        return_kv=False):
    _, _, layout = _gqa_layout(cfg, ctx, mode)
    if cfg.attention == "mla":
        y, latents = attn.mla_prefill(
            rmsnorm(x, p["attn_norm"], cfg.norm_eps), p, cfg, positions)
        x = x + y
        x = x + _mlp(x, p, cfg, ctx)
        return (x, latents) if return_kv else (x, None)
    if ctx.seq_parallel_norm:
        # Megatron-SP: the residual stream lives seq-sharded on the model
        # axis; GSPMD turns the per-block all-reduces into RS+AG pairs
        # (half the wire bytes)
        x = ctx.shard(x, "batch", "act_seq", None)
    q, k, v = _attn_qkv(x, p, cfg, positions, ctx)
    qp = positions if positions.ndim == 2 else positions[None, :]
    o = _flash_gqa(q, k, v, layout, q_positions=qp, window=window)
    x = x + jnp.einsum("bshe,hed->bsd", o, p["wo"])
    x = x + _mlp(x, p, cfg, ctx)
    return (x, (k, v)) if return_kv else (x, None)


def _attn_mlp_layer_decode(x, p, cfg, ctx, cache, lens, *, window):
    """cache: dict(k (B,S,kvx,hd), v ...) or MLA latents. Returns x, new cache."""
    _, _, layout = _gqa_layout(cfg, ctx, "serve")
    if cfg.attention == "mla":
        h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
        ml = cfg.mla
        ckv = rmsnorm(h @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)
        kpe = rope((h @ p["w_kr"])[:, :, None, :], lens[:, None], cfg.rope_theta)[:, :, 0]
        ckv_c = _insert_seq(cache["ckv"], ckv, lens)
        kpe_c = _insert_seq(cache["kpe"], kpe, lens)
        y = attn.mla_decode(h, p, cfg, ckv_c, kpe_c, lens)
        x = x + y
        x = x + _mlp(x, p, cfg, ctx)
        return x, {"ckv": ckv_c, "kpe": kpe_c}
    q, k, v = _attn_qkv(x, p, cfg, lens[:, None], ctx)
    kc = _insert_kv(cache["k"], k, lens)
    vc = _insert_kv(cache["v"], v, lens)
    o = _decode_gqa(q, kc, vc, lens, layout, window=window)
    x = x + jnp.einsum("bshe,hed->bsd", o, p["wo"])
    x = x + _mlp(x, p, cfg, ctx)
    return x, {"k": kc, "v": vc}


def _insert_kv(cache, new, lens):
    """cache (B,S,kv,hd); new (B,1,kv,hd); lens (B,)."""
    def one(c, n, l):
        return jax.lax.dynamic_update_slice(c, n, (l, 0, 0))
    return jax.vmap(one)(cache, new.astype(cache.dtype), lens.astype(jnp.int32))


def _decode_unrolled_stack(x, stack_params, cache, cfg, ctx, lens, window):
    """Unrolled decode over a homogeneous stack with stacked caches
    (L,B,S,kv,hd): per-layer params/cache use *static* indices, the new
    token is scattered in place, and attention dots read the cache slice
    directly (no materialised per-layer copies)."""
    kc, vc = cache["k"], cache["v"]
    L = kc.shape[0]
    B = x.shape[0]
    _, _, layout = _gqa_layout(cfg, ctx, "serve")
    bidx = jnp.arange(B, dtype=jnp.int32)
    for l in range(L):
        p = jax.tree_util.tree_map(lambda a: a[l], stack_params)
        q, k, v = _attn_qkv(x, p, cfg, lens[:, None], ctx)
        kc = kc.at[l, bidx, lens].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[l, bidx, lens].set(v[:, 0].astype(vc.dtype))
        o = _decode_gqa(q, kc[l].astype(q.dtype), vc[l].astype(q.dtype),
                        lens, layout, window=window)
        x = x + jnp.einsum("bshe,hed->bsd", o, p["wo"])
        x = x + _mlp(x, p, cfg, ctx)
    return x, {"k": kc, "v": vc}


def _insert_seq(cache, new, lens):
    """cache (B,S,r); new (B,1,r)."""
    def one(c, n, l):
        return jax.lax.dynamic_update_slice(c, n, (l, 0))
    return jax.vmap(one)(cache, new.astype(cache.dtype), lens.astype(jnp.int32))


def _maybe_remat(fn, ctx):
    if ctx.remat == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    return fn


# --------------------------------------------------------- full-sequence fwd
def forward(params, tokens, cfg: ModelConfig, ctx: ParallelContext, *,
            mode: str = "train", prefix_embeds=None, return_caches: bool = False):
    """tokens (B,S_tok) int32; prefix_embeds (B,P,d) for vlm/audio.
    Returns (logits (B,S,V), caches-or-None)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    x = ctx.shard(x, "batch", None, None)
    positions = jnp.arange(S, dtype=jnp.int32)
    window = cfg.swa_window if cfg.attention == "swa" else 0
    caches = {}

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def body(x, p):
            return _attn_mlp_layer_fwd(x, p, cfg, ctx, positions, mode,
                                       window=window, return_kv=return_caches)
        body = _maybe_remat(body, ctx)
        for name in ("dense_stack", "moe_stack"):
            if name in params:
                x, kv = jax.lax.scan(body, x, params[name])
                if return_caches:
                    caches[name] = kv
    elif cfg.family == "hybrid":
        x, caches = _hybrid_forward(x, params, cfg, ctx, positions, mode,
                                    return_caches)
    elif cfg.family == "ssm":
        x, caches = _xlstm_forward(x, params, cfg, ctx, return_caches)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = ctx.shard(logits, "batch", None, "vocab")
    return logits, (caches if return_caches else None)


def _hybrid_forward(x, params, cfg, ctx, positions, mode, return_caches):
    groups = cfg.n_layers // cfg.attn_every
    per = cfg.attn_every
    window = 0
    shared = params["shared_attn"]
    mstack = jax.tree_util.tree_map(
        lambda a: a.reshape(groups, per, *a.shape[1:]), params["mamba_stack"])

    def mamba_body(x, p):
        y, _ = ssm_lib.mamba2_forward(x, p, cfg)
        return x + y, None

    def group_body(x, pg):
        x, kv = _attn_mlp_layer_fwd(x, shared, cfg, ctx, positions, mode,
                                    window=window, return_kv=return_caches)
        x, _ = jax.lax.scan(mamba_body, x, pg)
        return x, kv

    x, kvs = jax.lax.scan(group_body, x, mstack)
    return x, ({"shared_attn": kvs} if return_caches else {})


def _xlstm_forward(x, params, cfg, ctx, return_caches):
    def group_body(x, pg):
        pm, ps = pg

        def m_body(x, p):
            y, st = xlstm_lib.mlstm_forward(x, p, cfg)
            return y, (st if return_caches else None)
        x, mst = jax.lax.scan(m_body, x, pm)
        x, sst = xlstm_lib.slstm_forward(x, ps, cfg)
        return x, ((mst, sst) if return_caches else None)

    x, states = jax.lax.scan(group_body, x,
                             (params["mlstm_stack"], params["slstm_stack"]))
    return x, ({"xlstm": states} if return_caches else {})


def loss_fn(params, batch, cfg: ModelConfig, ctx: ParallelContext):
    logits, _ = forward(params, batch["tokens"], cfg, ctx, mode="train",
                        prefix_embeds=batch.get("prefix_embeds"))
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:   # vlm/audio prefix: no loss on prefix
        pad = logits.shape[1] - labels.shape[1]
        labels = jnp.concatenate(
            [jnp.zeros((labels.shape[0], pad), labels.dtype), labels], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((labels.shape[0], pad), jnp.float32),
             jnp.ones((labels.shape[0], labels.shape[1] - pad), jnp.float32)],
            axis=1)
    else:
        mask = batch.get("mask")
    return softmax_xent(logits, labels, mask)


# --------------------------------------------------------------- serve paths
def init_decode_state(cfg: ModelConfig, ctx: ParallelContext, batch: int,
                      max_len: int, dtype=jnp.bfloat16):
    """Allocate the decode cache pytree (dense ring-buffer layout)."""
    hd = cfg.resolved_head_dim
    hp, kvp = heads_layout(cfg, ctx, "serve")
    state: Dict[str, Any] = {"lens": jnp.zeros((batch,), jnp.int32)}
    cdt = ctx.kv_cache_dtype or dtype
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        n_dense = cfg.moe.first_dense_layers if (cfg.moe and cfg.moe.n_experts) else cfg.n_layers
        n_moe = cfg.n_layers - n_dense if (cfg.moe and cfg.moe.n_experts) else 0
        caches = {}
        for name, n in (("dense_stack", n_dense), ("moe_stack", n_moe)):
            if n == 0:
                continue
            if cfg.attention == "mla":
                ml = cfg.mla
                caches[name] = {
                    "ckv": jnp.zeros((n, batch, max_len, ml.kv_lora_rank), cdt),
                    "kpe": jnp.zeros((n, batch, max_len, ml.qk_rope_head_dim), cdt),
                }
            else:
                caches[name] = {
                    "k": jnp.zeros((n, batch, max_len, kvp, hd), cdt),
                    "v": jnp.zeros((n, batch, max_len, kvp, hd), cdt),
                }
        state["caches"] = caches
    elif cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        state["caches"] = {"shared_attn": {
            "k": jnp.zeros((groups, batch, max_len, kvp, hd), cdt),
            "v": jnp.zeros((groups, batch, max_len, kvp, hd), cdt)}}
        h, cs = ssm_lib.init_mamba_state(cfg, batch, cdt)
        state["mamba"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)).copy(),
            (h, cs))
    elif cfg.family == "ssm":
        groups = cfg.n_layers // cfg.slstm_every
        per = cfg.slstm_every - 1
        mst = xlstm_lib.init_mlstm_state(cfg, batch, cdt)
        state["mlstm"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (groups, per, *a.shape)).copy(), mst)
        sst = xlstm_lib.init_slstm_state(cfg, batch)
        state["slstm"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (groups, *a.shape)).copy(), sst)
    return state


def decode_step(params, state, tokens, cfg: ModelConfig, ctx: ParallelContext):
    """One decode step for the whole batch. tokens (B,1) -> logits (B,1,V)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    lens = state["lens"]
    window = cfg.swa_window if cfg.attention == "swa" else 0
    new_state = dict(state)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        caches = state["caches"]
        new_caches = {}
        for name in ("dense_stack", "moe_stack"):
            if name not in params:
                continue
            if ctx.decode_unroll and cfg.attention != "mla":
                # §Perf: unrolled layers + static cache indexing — the scan's
                # per-layer cache slice/update round-trips become an in-place
                # one-token scatter (dots read the stacked cache directly)
                x, nc = _decode_unrolled_stack(x, params[name], caches[name],
                                               cfg, ctx, lens, window)
            else:
                def body(x, pc):
                    p, c = pc
                    x, nc = _attn_mlp_layer_decode(x, p, cfg, ctx, c, lens,
                                                   window=window)
                    return x, nc
                x, nc = jax.lax.scan(body, x, (params[name], caches[name]))
            new_caches[name] = nc
        new_state["caches"] = new_caches
    elif cfg.family == "hybrid":
        x, new_state = _hybrid_decode(x, params, state, cfg, ctx, lens)
    elif cfg.family == "ssm":
        x, new_state = _xlstm_decode(x, params, state, cfg, ctx)
        new_state["lens"] = lens

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    new_state["lens"] = lens + 1
    return logits, new_state


def _hybrid_decode(x, params, state, cfg, ctx, lens):
    groups = cfg.n_layers // cfg.attn_every
    per = cfg.attn_every
    shared = params["shared_attn"]
    mstack = jax.tree_util.tree_map(
        lambda a: a.reshape(groups, per, *a.shape[1:]), params["mamba_stack"])
    mstate = jax.tree_util.tree_map(
        lambda a: a.reshape(groups, per, *a.shape[1:]), state["mamba"])

    def group_body(x, inp):
        pg, cache_g, mst_g = inp
        x, nc = _attn_mlp_layer_decode(x, shared, cfg, ctx, cache_g, lens,
                                       window=0)

        def m_body(x, pm_st):
            pm, st = pm_st
            y, nst = ssm_lib.mamba2_decode(x, pm, cfg, st)
            return x + y, nst
        x, nms = jax.lax.scan(m_body, x, (pg, mst_g))
        return x, (nc, nms)

    x, (ncaches, nmamba) = jax.lax.scan(
        group_body, x, (mstack, state["caches"]["shared_attn"], mstate))
    new_state = dict(state)
    new_state["caches"] = {"shared_attn": ncaches}
    new_state["mamba"] = jax.tree_util.tree_map(
        lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), nmamba)
    return x, new_state


def _xlstm_decode(x, params, state, cfg, ctx):
    def group_body(x, inp):
        pm, ps, mst, sst = inp

        def m_body(x, pst):
            p, st = pst
            y, nst = xlstm_lib.mlstm_decode(x, p, cfg, st)
            return y, nst
        x, nmst = jax.lax.scan(m_body, x, (pm, mst))
        x, nsst = xlstm_lib.slstm_forward(x, ps, cfg, initial_state=sst)
        return x, (nmst, nsst)

    x, (nm, ns) = jax.lax.scan(
        group_body, x,
        (params["mlstm_stack"], params["slstm_stack"],
         state["mlstm"], state["slstm"]))
    new_state = dict(state)
    new_state["mlstm"] = nm
    new_state["slstm"] = ns
    return x, new_state


def prefill(params, tokens, cfg: ModelConfig, ctx: ParallelContext, *,
            prefix_embeds=None, max_len: Optional[int] = None,
            prompt_lens=None, cache_dtype=jnp.bfloat16):
    """Run the prompt, build a decode state. tokens (B,S). Returns
    (last-token logits (B,V), DecodeState)."""
    B, S = tokens.shape[0], tokens.shape[1]
    if prefix_embeds is not None:
        S = S + prefix_embeds.shape[1]
    max_len = max_len or S
    logits, caches = forward(params, tokens, cfg, ctx, mode="serve",
                             prefix_embeds=prefix_embeds, return_caches=True)
    if prompt_lens is None:
        prompt_lens = jnp.full((B,), S, jnp.int32)
    state = init_decode_state(cfg, ctx, B, max_len, cache_dtype)
    state["lens"] = prompt_lens.astype(jnp.int32)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        for name, kv in caches.items():
            tgt = state["caches"][name]
            if cfg.attention == "mla":
                ckv, kpe = kv
                tgt["ckv"] = _fill(tgt["ckv"], ckv.astype(tgt["ckv"].dtype))
                tgt["kpe"] = _fill(tgt["kpe"], kpe.astype(tgt["kpe"].dtype))
            else:
                k, v = kv
                tgt["k"] = _fill(tgt["k"], k.astype(tgt["k"].dtype))
                tgt["v"] = _fill(tgt["v"], v.astype(tgt["v"].dtype))
    elif cfg.family == "hybrid":
        k, v = caches["shared_attn"]
        tgt = state["caches"]["shared_attn"]
        tgt["k"] = _fill(tgt["k"], k.astype(tgt["k"].dtype))
        tgt["v"] = _fill(tgt["v"], v.astype(tgt["v"].dtype))
        # re-run mamba to harvest final states (cheap at small scale; the
        # engine path uses run_prefill_with_state below)
        state["mamba"] = _harvest_mamba_states(params, tokens, cfg, ctx,
                                               prefix_embeds)
    elif cfg.family == "ssm":
        mst, sst = _harvest_xlstm_states(params, tokens, cfg, ctx)
        state["mlstm"], state["slstm"] = mst, sst
    last = jnp.take_along_axis(
        logits, (state["lens"][:, None, None] - 1).astype(jnp.int32), axis=1)[:, 0]
    return last, state


def _fill(cache, kv):
    """cache (L,B,Smax,...); kv (L,B,S,...) -> write prefix."""
    return cache.at[:, :, :kv.shape[2]].set(kv)


def _harvest_mamba_states(params, tokens, cfg, ctx, prefix_embeds):
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    groups = cfg.n_layers // cfg.attn_every
    per = cfg.attn_every
    shared = params["shared_attn"]
    mstack = jax.tree_util.tree_map(
        lambda a: a.reshape(groups, per, *a.shape[1:]), params["mamba_stack"])

    def mamba_body(x, p):
        y, st = ssm_lib.mamba2_forward(x, p, cfg)
        return x + y, st

    def group_body(x, pg):
        x, _ = _attn_mlp_layer_fwd(x, shared, cfg, ctx, positions, "serve",
                                   window=0, return_kv=False)
        x, sts = jax.lax.scan(mamba_body, x, pg)
        return x, sts

    _, sts = jax.lax.scan(group_body, x, mstack)
    return jax.tree_util.tree_map(
        lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), sts)


def _harvest_xlstm_states(params, tokens, cfg, ctx):
    x = jnp.take(params["embed"], tokens, axis=0)

    def group_body(x, pg):
        pm, ps = pg

        def m_body(x, p):
            y, st = xlstm_lib.mlstm_forward(x, p, cfg)
            return y, st
        x, mst = jax.lax.scan(m_body, x, pm)
        x, sst = xlstm_lib.slstm_forward(x, ps, cfg)
        return x, (mst, sst)

    _, (mst, sst) = jax.lax.scan(group_body, x,
                                 (params["mlstm_stack"], params["slstm_stack"]))
    return mst, sst
