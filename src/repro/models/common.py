"""Shared numerics: RMSNorm, RoPE, initializers, losses."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq        # (..., S, half)
    ang = ang[..., None, :]                                      # head axis slot
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:2 * half]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    rot = jnp.concatenate([out1, out2], axis=-1)
    if d % 2:                                                    # odd head_dim tail
        rot = jnp.concatenate([rot, x[..., 2 * half:]], axis=-1)
    return rot.astype(x.dtype)


def dense_init(key, shape, in_axis_size: int, dtype=jnp.float32, scale: float = 1.0):
    std = scale / jnp.sqrt(jnp.asarray(in_axis_size, jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None, z_loss: float = 1e-4):
    """Cross-entropy with optional z-loss; logits (..., V) may be TP-sharded on V
    (GSPMD turns the reductions into small all-reduces)."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    # one-hot einsum keeps the vocab axis TP-sharded (GSPMD reduces with a
    # small all-reduce instead of all-gathering logits)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.einsum("...v,...v->...", logits, onehot)
    nll = lse - label_logit
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
