"""JSONL serialisation for event streams.

One event per line, keys in a fixed order, floats serialised by ``repr``
(Python's ``json`` round-trips doubles exactly), so two identical runs
produce byte-identical files and the differ can compare lines structurally
without tolerance fuzz.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, Iterator, List, Union

from repro.trace.events import Event, EventLog


def event_line(ev: Union[Event, Dict[str, Any]]) -> str:
    d = ev.to_dict() if isinstance(ev, Event) else ev
    return json.dumps(d, sort_keys=True)


def dump_events(events: Iterable[Union[Event, Dict[str, Any]]],
                path: str) -> int:
    """Write a recorded stream to ``path``; returns the event count."""
    n = 0
    with open(path, "w") as f:
        for ev in events:
            f.write(event_line(ev) + "\n")
            n += 1
    return n


def load_events(path: str) -> List[Dict[str, Any]]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def iter_events(path: str) -> Iterator[Dict[str, Any]]:
    with open(path) as f:
        for line in f:
            if line.strip():
                yield json.loads(line)


class JsonlWriter:
    """Streaming subscriber: writes each event as it is emitted, so tracing
    a run needs no in-memory recording. Use as a context manager, or call
    ``close()`` when the run drains::

        with JsonlWriter(path) as w:
            rt.events.subscribe(w)
            rt.run()
    """

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w")
        self.n = 0

    def __call__(self, ev: Event):
        self._f.write(event_line(ev) + "\n")
        self.n += 1

    def close(self):
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
