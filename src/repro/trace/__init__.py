"""repro.trace — the typed event spine under engine, cluster and autoscaler.

See ``docs/trace.md`` for the schema and ``python -m repro.trace diff`` for
the replay differ."""
from repro.trace.diff import DiffResult, diff_events
from repro.trace.events import KINDS, Event, EventEmitter, EventLog
from repro.trace.jsonl import (JsonlWriter, dump_events, iter_events,
                               load_events)

__all__ = [
    "KINDS", "Event", "EventEmitter", "EventLog",
    "JsonlWriter", "dump_events", "iter_events", "load_events",
    "DiffResult", "diff_events",
]
