"""CLI: ``python -m repro.trace diff a.jsonl b.jsonl [--context N]``.

Exit codes (lint-style): 0 = streams event-identical, 1 = divergence found,
2 = usage / unreadable input.
"""
from __future__ import annotations

import argparse
import sys

from repro.trace.diff import diff_events
from repro.trace.jsonl import load_events


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.trace",
        description="event-stream tools (see docs/trace.md)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    d = sub.add_parser("diff", help="compare two JSONL event streams")
    d.add_argument("a")
    d.add_argument("b")
    d.add_argument("--context", type=int, default=3,
                   help="identical events to print before the divergence")
    args = ap.parse_args(argv)

    try:
        ea, eb = load_events(args.a), load_events(args.b)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    res = diff_events(ea, eb, context=args.context,
                      label_a=args.a, label_b=args.b)
    print(res.report())
    return 0 if res.identical else 1


if __name__ == "__main__":
    sys.exit(main())
