"""The event spine: one typed, ordered record of everything that happens.

Every state transition in the engine, scheduler, allocator, cluster runtime
and autoscaler is emitted exactly once, from the one place that performs it,
as a frozen :class:`Event` on an :class:`EventLog`. Everything downstream —
``MetricsLog`` timelines, ``ClusterMetrics`` scaling/migration records, the
sim sanitizer's mirrors, the JSONL trace writer — is a *subscriber*: pure
derivations of the stream, never independent bookkeeping. Two runs of one
``Scenario`` + seed must produce identical streams (``repro.trace diff``),
which is a strictly stronger guarantee than summary-identical.

Emission is push-based and unbuffered: the log fans each event out to its
subscribers at emit time and, by default, retains nothing (recording is
opt-in via ``EventLog(record=True)`` / ``enable_recording()``), so the spine
adds no per-run memory unless a trace is actually wanted.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

# Every transition the spine records. One emission site per kind:
#
#   arrival       engine.submit            request entered an engine's log
#   admit         scheduler admission      WAITING request became RUNNING
#   resume        scheduler admission      PREEMPTED request re-admitted
#   prefill       engine step              one executed prefill chunk
#   decode_step   engine step              one decode batch (rids list)
#   preempt       scheduler._preempt       victim freed + requeued (recompute)
#   eject         engine.eject             request left an engine unfinished
#   inject        engine.inject            migrated request adopted (success)
#   finish        engine step              request completed, left the engine
#   kv_alloc      allocator.grow           pages added to a rid's table
#   kv_free       allocator.free           a rid's table released
#   step          engine step              telemetry snapshot (TimelinePoint)
#   mint          runtime.add_worker       replica provisioned, cold start on
#   join          runtime (warm-up done)   replica entered its pool
#   retire        runtime.retire_worker    replica left the pools, draining
#   drained       runtime (drain done)     replica went dark, t_retire stamped
#   scale_decision autoscaler.tick         controller resolved a nonzero delta
#   kv_transfer   runtime (harvest)        migration in flight (src, ready)
#   rebalance     runtime (rebalance tick) decode→decode migration decided
#                                          (src pressure, dst, victim rid)
#   run_end       runtime.run              fleet drained, makespan stamped
KINDS = (
    "arrival", "admit", "resume", "prefill", "decode_step", "preempt",
    "eject", "inject", "finish", "kv_alloc", "kv_free", "step",
    "mint", "join", "retire", "drained", "scale_decision", "kv_transfer",
    "rebalance", "run_end",
)
_KIND_SET = frozenset(KINDS)


@dataclasses.dataclass(frozen=True)
class Event:
    """One transition: when, what, to whom, where, with what details.

    ``payload`` holds plain scalars (and lists of scalars) only — the event
    must serialise to JSONL and compare bit-exactly across runs. ``ref`` is
    the live ``Request`` (or ``Worker``) the transition acted on, carried for
    in-process subscribers (the metrics consumers need the object, not a
    copy); it is excluded from equality, repr and serialisation."""
    t: float
    kind: str
    rid: Optional[int] = None
    worker: str = ""
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)
    ref: Any = dataclasses.field(default=None, compare=False, repr=False)

    def __post_init__(self):
        if self.kind not in _KIND_SET:
            raise ValueError(f"unknown event kind {self.kind!r} "
                             f"(have {KINDS})")

    def to_dict(self) -> Dict[str, Any]:
        """JSONL row — everything except the live ``ref``."""
        return {"t": self.t, "kind": self.kind, "rid": self.rid,
                "worker": self.worker, "payload": self.payload}


class EventLog:
    """Ordered fan-out point for one stream (an engine's, or the fleet's).

    Subscribers are called synchronously in subscription order at emit time
    — the stream IS the ordering, so consumers see transitions exactly as
    they happened. ``events`` is populated only when recording (memory stays
    O(1) on the default path). An engine log can forward into a fleet log by
    subscribing the fleet log's ``emit``."""

    def __init__(self, record: bool = False):
        self.events: Optional[List[Event]] = [] if record else None
        self._subs: List[Callable[[Event], None]] = []

    @property
    def recording(self) -> bool:
        return self.events is not None

    def enable_recording(self):
        if self.events is None:
            self.events = []

    def subscribe(self, fn: Callable[[Event], None]):
        self._subs.append(fn)

    def unsubscribe(self, fn: Callable[[Event], None]):
        self._subs.remove(fn)

    def emit(self, ev: Event):
        if self.events is not None:
            self.events.append(ev)
        for fn in self._subs:
            fn(ev)


class EventEmitter:
    """The one sanctioned way to put an event on a log.

    Bound to a clock (the owning engine's ``now``, or the fleet makespan)
    and a worker name, so emission sites stay one-liners:
    ``emitter.emit("preempt", rid=r.rid, generated=r.generated)``. The
    worker name is stamped by ``Worker.__post_init__`` — a standalone engine
    emits with an empty name."""

    def __init__(self, log: EventLog, clock: Callable[[], float],
                 worker: str = ""):
        self.log = log
        self.clock = clock
        self.worker = worker

    def emit(self, kind: str, rid: Optional[int] = None, ref: Any = None,
             t: Optional[float] = None, worker: Optional[str] = None,
             **payload) -> Event:
        # ``worker`` overrides the bound name: fleet-level emitters stamp the
        # SUBJECT replica on lifecycle events (mint/join/retire/drained),
        # not the emitting fleet
        ev = Event(t=self.clock() if t is None else t, kind=kind, rid=rid,
                   worker=self.worker if worker is None else worker,
                   payload=payload, ref=ref)
        self.log.emit(ev)
        return ev
