"""Event-level trace differ: are two runs the *same run*?

Summary-identical is a weak guarantee — two runs can agree on every
aggregate and still have routed, preempted and migrated differently (the
divergence just cancelled). The differ compares streams event by event and
reports the FIRST divergence with surrounding context, which is exactly
where a determinism bug entered: everything before the reported index is
identical, so the named event is the earliest observable symptom.

``python -m repro.trace diff a.jsonl b.jsonl`` exits 0 when the streams are
event-identical and 1 otherwise (lint-style, CI-friendly).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Union

from repro.trace.events import Event


def _rows(events: Sequence[Union[Event, Dict[str, Any]]]
          ) -> List[Dict[str, Any]]:
    return [e.to_dict() if isinstance(e, Event) else e for e in events]


def _fmt(row: Dict[str, Any]) -> str:
    rid = "" if row.get("rid") is None else f" rid={row['rid']}"
    w = f" @{row['worker']}" if row.get("worker") else ""
    payload = row.get("payload") or {}
    extra = " ".join(f"{k}={payload[k]}" for k in sorted(payload))
    return f"t={row['t']:.6f} {row['kind']}{rid}{w}" \
           + (f" {extra}" if extra else "")


@dataclasses.dataclass(frozen=True)
class DiffResult:
    """Outcome of comparing two streams. ``index`` is the first position
    where they disagree (None when identical); ``fields`` names the event
    fields that differ there (empty when one stream simply ended)."""
    n_a: int
    n_b: int
    index: Optional[int]
    fields: tuple = ()
    report_lines: tuple = ()

    @property
    def identical(self) -> bool:
        return self.index is None and self.n_a == self.n_b

    def report(self) -> str:
        return "\n".join(self.report_lines)


def diff_events(a: Sequence[Union[Event, Dict[str, Any]]],
                b: Sequence[Union[Event, Dict[str, Any]]],
                context: int = 3,
                label_a: str = "a", label_b: str = "b") -> DiffResult:
    """Positional comparison of two event streams.

    Returns a :class:`DiffResult` whose ``report()`` is human-readable: the
    first diverging index, the differing fields, both events, and the last
    ``context`` identical events leading up to the divergence (the shared
    prefix that localises the bug)."""
    ra, rb = _rows(a), _rows(b)
    n = min(len(ra), len(rb))
    for i in range(n):
        if ra[i] == rb[i]:
            continue
        fields = tuple(k for k in ("t", "kind", "rid", "worker", "payload")
                       if ra[i].get(k) != rb[i].get(k))
        lines = [f"streams diverge at event {i} "
                 f"(of {len(ra)} in {label_a}, {len(rb)} in {label_b}); "
                 f"differing fields: {', '.join(fields) or '?'}"]
        lo = max(i - context, 0)
        for j in range(lo, i):
            lines.append(f"  = [{j}] {_fmt(ra[j])}")
        lines.append(f"  < [{i}] {_fmt(ra[i])}   ({label_a})")
        lines.append(f"  > [{i}] {_fmt(rb[i])}   ({label_b})")
        return DiffResult(n_a=len(ra), n_b=len(rb), index=i, fields=fields,
                          report_lines=tuple(lines))
    if len(ra) != len(rb):
        longer, ln = (label_a, ra) if len(ra) > len(rb) else (label_b, rb)
        lines = [f"streams identical for {n} events, then {longer} "
                 f"continues ({len(ra)} vs {len(rb)} events)"]
        for j in range(n, min(n + context, len(ln))):
            lines.append(f"  + [{j}] {_fmt(ln[j])}   ({longer} only)")
        return DiffResult(n_a=len(ra), n_b=len(rb), index=n,
                          report_lines=tuple(lines))
    return DiffResult(
        n_a=len(ra), n_b=len(rb), index=None,
        report_lines=(f"streams identical: {len(ra)} events",))
