"""Pure-jnp oracle for the flash-attention kernel (materialises S x S scores;
small shapes only — used by the kernel sweep tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, lens, *, causal=True, window=0, scale=None):
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k, g, axis=2).astype(jnp.float32)   # (B,Skv,H,D)
    vf = jnp.repeat(v, g, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    valid = k_pos < lens.astype(jnp.int32)[:, None, None, None]
    if causal:
        valid = valid & (k_pos <= q_pos)
    if window and window > 0:
        valid = valid & (k_pos > q_pos - window)
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(valid, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    w = jnp.where(l > 0, p / jnp.maximum(l, 1e-30), 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vf)
    return out.astype(q.dtype)
