"""Pallas TPU flash-attention (prefill) kernel.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv-block axis is the
innermost (sequential) dimension; online-softmax stats (m, l) and the output
accumulator live in VMEM scratch and persist across kv-block steps.

BlockSpec tiling (MXU-aligned 128x128 defaults):
  q   (1, block_q, 1, D)   revisited for every kv block
  k/v (1, block_k, 1, D)   kv head = q_head // group
  out (1, block_q, 1, D)   written once, on the last kv block

Causal + sliding-window masking is applied inside the kernel from the global
block offsets; kv blocks strictly above the diagonal (or outside the window)
are skipped with pl.when so the MXU work is elided, not just masked.
VMEM budget per grid cell: q/k/v tiles 3x32KB + scores 64KB + acc 64KB (fp32)
~= 0.2 MB, far under the ~16 MB/core budget -> Pallas double-buffers freely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int,
                  causal: bool, window: int, seq_kv: int):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # skip fully-masked kv blocks (strictly above the causal diagonal, or
    # entirely left of the sliding window)
    run = jnp.bool_(True)
    if causal:
        run = k_start <= q_start + block_q - 1
    if window and window > 0:
        run = jnp.logical_and(run, k_start + block_k - 1
                              > q_start - window)

    @pl.when(run)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :]
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(q.astype(k.dtype), k,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        valid = k_pos < lens_ref[b]
        if causal:
            valid = jnp.logical_and(valid, k_pos <= q_pos)
        if window and window > 0:
            valid = jnp.logical_and(valid, k_pos > q_pos - window)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        # explicit re-mask: fully-masked rows would otherwise get exp(0)=1
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        out = jnp.where(l[:, None] > 0,
                        acc_ref[...] / jnp.maximum(l[:, None], 1e-30), 0.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"))
def flash_attention_kernel(q, k, v, lens, *, causal=True, window=0,
                           scale=None, block_q=128, block_k=128,
                           interpret=False):
    """q (B,Sq,H,D); k,v (B,Skv,KV,D); lens (B,) int32 valid kv length.
    Returns (B,Sq,H,D). H % KV == 0 (GQA via kv-head revisiting)."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, \
        f"seq ({Sq},{Skv}) must tile by ({block_q},{block_k})"
    grid = (B, H, Sq // block_q, Skv // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, seq_kv=Skv)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # index maps receive the scalar-prefetch ref as a trailing arg
                pl.BlockSpec((1, block_q, 1, D),
                             lambda b, h, iq, ik, lens: (b, iq, h, 0)),
                pl.BlockSpec((1, block_k, 1, D),
                             lambda b, h, iq, ik, lens: (b, ik, h // g, 0)),
                pl.BlockSpec((1, block_k, 1, D),
                             lambda b, h, iq, ik, lens: (b, ik, h // g, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_q, 1, D),
                                   lambda b, h, iq, ik, lens: (b, iq, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        interpret=interpret,
    )(lens.astype(jnp.int32), q, k, v)
