"""jit'd public wrapper: dispatches the Pallas kernel on TPU, interpret mode on
CPU (correctness), with shape padding to tile boundaries."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, lens=None, *, causal=True, window=0, scale=None,
                    block_q=128, block_k=128, interpret=None):
    """q (B,Sq,H,D); k,v (B,Skv,KV,D); lens (B,) optional valid kv lengths."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    if lens is None:
        lens = jnp.full((B,), Skv, jnp.int32)
    if interpret is None:
        interpret = not _on_tpu()
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    out = flash_attention_kernel(q, k, v, lens, causal=causal, window=window,
                                 scale=scale, block_q=block_q,
                                 block_k=block_k, interpret=interpret)
    return out[:, :Sq] if pad_q else out
