"""Pure-jnp oracle: gathers pages into a contiguous cache, dense attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pages, v_pages, block_tables, lens, *, scale=None):
    B, KV, G, D = q.shape
    page = k_pages.shape[1]
    max_blocks = block_tables.shape[1]
    scale = scale if scale is not None else D ** -0.5
    # gather (B, max_blocks*page, KV, D)
    kc = k_pages[block_tables].reshape(B, max_blocks * page, KV, D)
    vc = v_pages[block_tables].reshape(B, max_blocks * page, KV, D)
    qf = q.astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bskd->bkgs", qf, kc.astype(jnp.float32))
    pos = jnp.arange(max_blocks * page)
    valid = pos[None, :] <= lens.astype(jnp.int32)[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(valid[:, None, None, :], jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    w = jnp.where(l > 0, p / jnp.maximum(l, 1e-30), 0.0)
    out = jnp.einsum("bkgs,bskd->bkgd", w, vc.astype(jnp.float32))
    return out.astype(q.dtype)
