"""jit'd public wrapper for the paged-attention decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def paged_attention(q, k_pages, v_pages, block_tables, lens, *, scale=None,
                    interpret=None):
    """q (B,H,D) new-token queries (H = KV*G, kv-major); k/v_pages
    (P, page, KV, D); block_tables (B, max_blocks); lens (B,)."""
    B, H, D = q.shape
    KV = k_pages.shape[2]
    G = H // KV
    if interpret is None:
        interpret = not _on_tpu()
    qk = q.reshape(B, KV, G, D)
    out = paged_attention_kernel(qk, k_pages, v_pages, block_tables, lens,
                                 scale=scale, interpret=interpret)
    return out.reshape(B, H, D)
