"""Pallas TPU paged-attention decode kernel.

One new token per sequence attends over a block-table-indexed paged KV cache
(vLLM layout, page = 16 tokens, DESIGN.md §2 hardware adaptation: the CUDA
warp-reduction kernel becomes a VMEM-blocked online-softmax loop; pages are
DMA'd HBM->VMEM by the BlockSpec index_map driven from the scalar-prefetched
block table).

Grid: (batch, kv_heads, num_pages) — pages innermost/sequential; the q-group
accumulator (g, D) and stats live in VMEM scratch across page steps.

  q        (B, KV, G, D)    revisited per page
  k/v page (1, page, 1, D)  page id = block_table[b, j]
  out      (B, KV, G, D)    written on the last page

Pages past ceil(len/page) are skipped with pl.when (DMA still issued for the
block — acceptable at page granularity; a fully dynamic grid would need
ragged iteration, noted as a TPU-side future optimisation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)
    npg = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = lens_ref[b] + 1          # cache holds positions 0..len inclusive
    n_used = (seq_len + page - 1) // page

    @pl.when(j < n_used)
    def _step():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale     # (G, D)
        k = k_ref[0, :, 0, :]                                 # (page, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(q.astype(k.dtype), k,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G,page)
        pos = j * page + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        valid = pos < seq_len
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == npg - 1)
    def _finish():
        l = l_ref[...]
        out = jnp.where(l[:, None] > 0,
                        acc_ref[...] / jnp.maximum(l[:, None], 1e-30), 0.0)
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "interpret"))
def paged_attention_kernel(q, k_pages, v_pages, block_tables, lens, *,
                           scale=None, interpret=False):
    """q (B,KV,G,D); k/v_pages (P, page, KV, D); block_tables (B, max_blocks)
    int32 page ids; lens (B,) index of the newest token. Returns (B,KV,G,D)."""
    B, KV, G, D = q.shape
    page = k_pages.shape[1]
    max_blocks = block_tables.shape[1]
    scale = scale if scale is not None else D ** -0.5
    grid = (B, KV, max_blocks)

    kernel = functools.partial(_paged_kernel, page=page, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,     # block_tables, lens
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D),
                             lambda b, h, j, tables, lens: (b, h, 0, 0)),
                pl.BlockSpec((1, page, 1, D),
                             lambda b, h, j, tables, lens:
                             (tables[b, j], 0, h, 0)),
                pl.BlockSpec((1, page, 1, D),
                             lambda b, h, j, tables, lens:
                             (tables[b, j], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, j, tables, lens: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lens.astype(jnp.int32), q,
      k_pages, v_pages)
