"""The paper's own evaluated model family (§III-C).

DeepSeek-R1-Distill (Llama-8B, Qwen-14B, Qwen-32B, Llama-70B) — dense GQA,
plus DeepSeek-R1-671B — MoE with Multi-Head Latent Attention (MLA).
These configs drive the paper-reproduction benchmarks (Figs 2-15) and the
parallelism planner regression tests; llama3-405b (also a paper subject) is an
assigned arch and lives in its own module.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

DS_DISTILL_8B = ModelConfig(
    name="ds-distill-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256, attention="full", rope_theta=500000.0,
    notes="DeepSeek-R1-Distill-Llama-8B (paper's small-model subject)")

DS_DISTILL_14B = ModelConfig(
    name="ds-distill-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab=152064, attention="full", rope_theta=1000000.0,
    notes="DeepSeek-R1-Distill-Qwen-14B")

DS_DISTILL_32B = ModelConfig(
    name="ds-distill-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=27648, vocab=152064, attention="full", rope_theta=1000000.0,
    notes="DeepSeek-R1-Distill-Qwen-32B (paper: 262 KB/token, the DP->TP crossover)")

DS_DISTILL_70B = ModelConfig(
    name="ds-distill-70b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256, attention="full", rope_theta=500000.0,
    notes="DeepSeek-R1-Distill-Llama-70B (paper: 328 KB/token)")

DEEPSEEK_R1_671B = ModelConfig(
    name="deepseek-r1-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=18432, vocab=129280, attention="mla", rope_theta=10000.0,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, first_dense_layers=3,
                  capacity_factor=1.25),
    notes="paper's sparse frontier subject; MLA compresses KV to 576/token/layer")

PAPER_MODELS = {m.name: m for m in (
    DS_DISTILL_8B, DS_DISTILL_14B, DS_DISTILL_32B, DS_DISTILL_70B,
    DEEPSEEK_R1_671B)}
