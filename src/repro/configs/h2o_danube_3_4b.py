"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.
SWA bounds the KV working set -> long_500k decode runs for this arch
(sub-quadratic: per-step attention touches only the window).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "h2o-danube-3-4b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab=32000,
    attention="swa",
    swa_window=4096,
    rope_theta=10000.0,
    notes="sliding-window attention caps per-request KV (capacity trap shifts right)",
)
