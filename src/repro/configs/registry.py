"""Architecture + input-shape registry.

``get_config(arch_id)`` resolves any assigned architecture or paper model.
``SHAPES`` defines the four assigned input-shape cells; ``cells()`` enumerates
the (arch x shape) grid with the long_500k sub-quadratic skip rule applied.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

from repro.configs import (h2o_danube_3_4b, internvl2_76b, kimi_k2_1t,
                           llama3_2_3b, llama3_405b, musicgen_medium,
                           phi3_5_moe_42b, qwen3_14b, xlstm_350m, zamba2_2_7b)
from repro.configs.base import ModelConfig, reduced
from repro.configs.paper_models import PAPER_MODELS

_ASSIGNED = (llama3_2_3b, qwen3_14b, h2o_danube_3_4b, llama3_405b,
             internvl2_76b, musicgen_medium, phi3_5_moe_42b, kimi_k2_1t,
             zamba2_2_7b, xlstm_350m)

ARCHS: Dict[str, ModelConfig] = {m.ARCH_ID: m.CONFIG for m in _ASSIGNED}
ALL_MODELS: Dict[str, ModelConfig] = {**ARCHS, **PAPER_MODELS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}


def get_config(arch_id: str) -> ModelConfig:
    try:
        return ALL_MODELS[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ALL_MODELS)}") from None


def get_smoke_config(arch_id: str) -> ModelConfig:
    return reduced(get_config(arch_id))


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """long_500k requires sub-quadratic attention (brief); decoders have all
    other shapes. Returns (applicable, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "pure full-attention arch: long_500k skipped per brief (DESIGN.md §4)"
    return True, ""


def cells(include_skipped: bool = False) -> Iterator[Tuple[str, str, Optional[str]]]:
    """Yield (arch_id, shape_name, skip_reason|None) over the 40-cell grid."""
    for arch_id, cfg in ARCHS.items():
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            if ok:
                yield arch_id, shape.name, None
            elif include_skipped:
                yield arch_id, shape.name, why
