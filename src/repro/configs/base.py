"""Model configuration schema.

One ``ModelConfig`` describes everything the substrate needs to build an
architecture: the transformer geometry, the attention flavour (full / sliding
window / MLA), MoE routing, and SSM/xLSTM block layout for the hybrid and
attention-free families.

All assigned architectures (and the paper's own model family) are expressed as
instances of this dataclass — see the sibling ``<arch>.py`` modules and
``registry.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-Head Latent Attention (DeepSeek-R1 family, §II-B of the paper)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512          # latent the KV cache stores (decouples cache from heads)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 2
    d_ff_expert: int = 0
    n_shared_experts: int = 0        # DeepSeek/Kimi-style always-on shared expert(s)
    first_dense_layers: int = 0      # leading dense layers before MoE starts
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block geometry."""
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 128                 # chunk length for the chunked-scan train path


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    attention: str = "full"          # full | swa | mla | none
    swa_window: int = 4096
    qk_norm: bool = False
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one *shared* (weight-tied) attention+MLP block inserted
    # every `attn_every` SSM layers.  attn_every == 0 -> no attention blocks.
    attn_every: int = 0
    # xlstm: every `slstm_every`-th block is an sLSTM (scalar-memory) block,
    # the rest are mLSTM (matrix-memory).  0 -> all mLSTM.
    slstm_every: int = 0
    # modality frontends (vlm/audio) are stubs: input_specs() hands the
    # backbone precomputed patch/frame embeddings of this length.
    frontend_prefix_len: int = 0
    notes: str = ""

    # ------------------------------------------------------------------ derived
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.attention == "none"

    @property
    def is_subquadratic(self) -> bool:
        """True if long-context decode is admissible (brief: run long_500k)."""
        return self.family in ("ssm", "hybrid") or self.attention == "swa"

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """Per-token KV-cache footprint across all layers (paper §II-B)."""
        if self.attention == "mla":
            assert self.mla is not None
            per_layer = self.mla.kv_lora_rank + self.mla.qk_rope_head_dim
            n_attn = self.n_layers
        elif self.attention == "none":
            return 0  # constant state instead — see state_bytes_per_seq
        else:
            per_layer = 2 * self.n_kv_heads * self.resolved_head_dim
            n_attn = self.n_attention_layers
        return per_layer * n_attn * dtype_bytes

    @property
    def n_attention_layers(self) -> int:
        if self.family == "hybrid" and self.attn_every:
            return self.n_layers // self.attn_every
        if self.attention == "none":
            return 0
        return self.n_layers

    @property
    def n_ssm_layers(self) -> int:
        if self.family == "hybrid" and self.attn_every:
            return self.n_layers
        if self.family == "ssm":
            return 0  # xlstm uses its own blocks, not mamba
        return 0

    def state_bytes_per_seq(self, dtype_bytes: int = 4) -> int:
        """Constant per-sequence recurrent state (SSM / xLSTM / conv)."""
        total = 0
        if self.ssm is not None:
            d_inner = self.ssm.expand * self.d_model
            n_heads = d_inner // self.ssm.head_dim
            per_layer = n_heads * self.ssm.head_dim * self.ssm.d_state \
                + d_inner * (self.ssm.conv_width - 1)
            total += per_layer * self.n_layers * dtype_bytes
        if self.family == "ssm":  # xlstm matrix memory
            hd = self.resolved_head_dim
            per_layer = self.n_heads * hd * hd + 2 * self.n_heads * hd + 4 * self.n_heads
            total += per_layer * self.n_layers * dtype_bytes
        return total

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS = 6·N·D)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        n = emb
        hd = self.resolved_head_dim
        for i in range(self.n_layers):
            n += self._layer_params(i, hd)
        if self.family == "hybrid" and self.attn_every:
            # one weight-tied shared attention+MLP block (counted once)
            n += self._attn_params(hd) + 3 * d * self.d_ff + 2 * d
        return n

    def active_param_count(self) -> int:
        """Active parameters per token (MoE activates top_k + shared)."""
        if self.moe is None or self.moe.n_experts == 0:
            return self.param_count()
        d = self.d_model
        m = self.moe
        total = self.param_count()
        moe_layers = self.n_layers - m.first_dense_layers
        inactive = moe_layers * (m.n_experts - m.top_k) * 3 * d * m.d_ff_expert
        return total - inactive

    # -- internals -------------------------------------------------------------
    def _attn_params(self, hd: int) -> int:
        d = self.d_model
        if self.attention == "mla":
            assert self.mla is not None
            ml = self.mla
            qk_head = ml.qk_nope_head_dim + ml.qk_rope_head_dim
            return (d * ml.q_lora_rank + ml.q_lora_rank * self.n_heads * qk_head
                    + d * (ml.kv_lora_rank + ml.qk_rope_head_dim)
                    + ml.kv_lora_rank * self.n_heads * (ml.qk_nope_head_dim + ml.v_head_dim)
                    + self.n_heads * ml.v_head_dim * d)
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

    def _layer_params(self, i: int, hd: int) -> int:
        d = self.d_model
        if self.family == "ssm":      # xlstm block
            if self.slstm_every and (i + 1) % self.slstm_every == 0:
                return 4 * d * d + 4 * self.n_heads * hd * hd + 2 * d * 4 * d  # approx
            return 2 * d * 2 * d + 2 * d * d + 3 * d * d                        # mLSTM approx
        if self.family == "hybrid":   # mamba2 layer (shared attn counted separately)
            assert self.ssm is not None
            di = self.ssm.expand * d
            nh = di // self.ssm.head_dim
            ds = self.ssm.d_state
            return (d * (2 * di + 2 * ds + nh)            # in_proj (x,z,B,C,dt)
                    + (di + 2 * ds) * self.ssm.conv_width  # short conv
                    + 3 * nh + di                          # A_log, D, dt_bias, norm
                    + di * d)                              # out_proj
        n = 2 * d  # norms
        n += self._attn_params(hd)
        if self.moe is not None and self.moe.n_experts and i >= self.moe.first_dense_layers:
            m = self.moe
            n += d * m.n_experts  # router
            n += (m.n_experts + m.n_shared_experts) * 3 * d * m.d_ff_expert
        else:
            n += 3 * d * self.d_ff
        return n


def reduced(cfg: ModelConfig, *, layers: int = 0) -> ModelConfig:
    """Family-preserving reduced config for CPU smoke tests."""
    n_layers = layers or (4 if (cfg.attn_every or cfg.slstm_every) else 2)
    if cfg.attn_every:
        n_layers = max(n_layers, 2 * cfg.attn_every)  # keep ≥2 shared-attn insertions
        n_layers = 2 * cfg.attn_every
    if cfg.slstm_every:
        n_layers = 2 * cfg.slstm_every
    kv = min(cfg.n_kv_heads, 2)
    heads = max(4, kv * min(cfg.q_per_kv, 2))
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
        frontend_prefix_len=min(cfg.frontend_prefix_len, 4),
        swa_window=16,
    )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
    if cfg.moe is not None and cfg.moe.n_experts:
        # capacity_factor 8 -> no token drops at smoke scale, so the batched
        # and incremental paths agree exactly (drop semantics get their own
        # unit test in tests/test_moe.py)
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            capacity_factor=8.0)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, expand=2, head_dim=16, conv_width=4, chunk=8)
    return dataclasses.replace(cfg, **kw)
