"""llama3-405b — dense frontier, GQA, 128k vocab [arXiv:2407.21783; unverified].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
This is the paper's dense-frontier subject (Fig 10/14: TP8 986s vs PP8 7537s;
KV = 1.05 MB/token in FP16 -> the "Reasoning Cliff" arch).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "llama3-405b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab=128256,
    attention="full",
    rope_theta=500000.0,
    notes="paper's dense frontier model; 1.05MB/token KV, interconnect+HBM bound",
)
