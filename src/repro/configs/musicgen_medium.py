"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=1536 24H (MHA: kv=24) d_ff=6144 vocab=2048.
The EnCodec frontend is a STUB per the brief: input_specs() supplies
precomputed frame embeddings (delay-pattern codebook interleave is upstream
of the backbone). Full MHA -> the highest kv-head count in the pool, which
stresses the KV-capacity axis per parameter.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "musicgen-medium"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    attention="full",
    rope_theta=10000.0,
    frontend_prefix_len=0,
    notes="audio token decoder; MHA (kv=24) maximizes KV bytes/token/layer ratio",
)
