"""qwen3-14b — qk_norm + GQA [hf:Qwen/Qwen3-8B; hf].

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
Paper regime: the 14B DP-dominant point of Fig 7/8 (Obs 5).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-14b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab=151936,
    attention="full",
    qk_norm=True,
    rope_theta=1000000.0,
    notes="qk_norm GQA dense",
)
