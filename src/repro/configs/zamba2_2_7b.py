"""zamba2-2.7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf].

54L d_model=2560 32H (MHA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Hybrid: 54 Mamba2 layers with ONE weight-tied (shared) attention+MLP block
invoked every 6 layers (9 invocations, 9 distinct KV caches, tied weights).
O(1) SSM state + small periodic KV -> the capacity trap largely vanishes;
long_500k decode runs for this arch.
"""
from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "zamba2-2.7b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab=32000,
    attention="full",       # flavour of the shared attention block
    rope_theta=10000.0,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, conv_width=4, chunk=128),
    attn_every=6,
    notes="Mamba2 + weight-tied shared attention block every 6 layers",
)
