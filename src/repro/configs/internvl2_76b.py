"""internvl2-76b — VLM: InternViT + InternLM2 backbone [arXiv:2404.16821; unverified].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The vision frontend (InternViT) is a STUB per the brief: input_specs() supplies
precomputed patch embeddings of length ``frontend_prefix_len`` which the
backbone consumes as a prefix before the text tokens.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "internvl2-76b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    attention="full",
    rope_theta=1000000.0,
    frontend_prefix_len=256,   # one 448x448 tile -> 256 patch embeddings
    notes="LLM backbone only; ViT frontend stubbed as precomputed patch embeddings",
)
