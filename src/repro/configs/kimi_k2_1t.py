"""kimi-k2-1t-a32b — trillion-param MoE (paper-table) [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840, MoE 384e top-8.
Frontier-sparse analogue of the paper's DeepSeek-R1-671B (Obs 6): low active
parameter count -> compute-to-communication ratio collapses under high-degree
TP; hybrid EP+PP+low-TP preferred.
"""
from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "kimi-k2-1t-a32b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=18432,              # dense d_ff for the first dense layer
    vocab=163840,
    attention="full",
    rope_theta=50000.0,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, first_dense_layers=1,
                  capacity_factor=1.25),
    notes="384-expert top-8; 24 experts per device on 16-way EP; ~32B active",
)
