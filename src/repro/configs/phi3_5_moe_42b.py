"""phi3.5-moe-42b-a6.6b — 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L d_model=4096 32H (GQA kv=8) expert d_ff=6400 vocab=32064, MoE 16e top-2.
Paper regime: the MoE divergence (Obs 6) at mid scale - sync-sensitive,
favors lower TP degree + expert parallelism.
"""
from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,               # dense-equivalent ff (unused when every layer is MoE)
    vocab=32064,
    attention="full",
    rope_theta=10000.0,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400,
                  n_shared_experts=0, first_dense_layers=0,
                  capacity_factor=1.25),
    notes="every layer MoE; EP maps 1 expert/device on a 16-way model axis",
)
