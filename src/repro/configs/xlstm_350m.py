"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H d_ff=0 vocab=50304.
Attention-free: matrix-memory (mLSTM) and scalar-memory (sLSTM) recurrence.
d_ff=0 -> blocks carry their own up/down projections (no separate FFN).
Every 8th block is sLSTM (the 7:1 xLSTM ratio); the rest are mLSTM.
Constant decode state -> long_500k runs; paged-KV machinery is inapplicable
(see DESIGN.md §4) and the engine uses fixed-size state slots instead.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "xlstm-350m"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab=50304,
    attention="none",
    slstm_every=8,
    notes="attention-free xLSTM; O(1) state, no KV cache",
)
