"""llama3.2-3b — small dense llama3 [hf:meta-llama/Llama-3.2-1B; unverified].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
Paper regime: small-dense / DP-dominant (§IV, Obs 4-5).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "llama3.2-3b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=128256,
    attention="full",
    rope_theta=500000.0,
    tie_embeddings=True,
    notes="small llama3; DP-dominant regime in the paper's taxonomy",
)
