"""CLI for repro.obs — post-hoc analysis of JSONL event traces.

    python -m repro.obs report trace.jsonl [--json] [--window S]
    python -m repro.obs perfetto trace.jsonl -o trace.perfetto.json

``report`` prints the bottleneck report (text by default, ``--json`` for the
machine-readable dict); ``perfetto`` writes a Chrome-trace JSON loadable in
ui.perfetto.dev. Input is one JSON event per line, as written by
``REPRO_TRACE_OUT`` / the benchmarks' ``--trace-out``. Exit codes: 0 on
success, 2 on unreadable/empty input.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List


def _load(path: str) -> List[Dict[str, Any]]:
    rows: List[Dict[str, Any]] = []
    try:
        with open(path, "r") as f:
            for i, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    row = json.loads(line)
                except json.JSONDecodeError as e:
                    print(f"error: {path}:{i}: not JSON ({e})",
                          file=sys.stderr)
                    raise SystemExit(2)
                if not isinstance(row, dict) or "kind" not in row:
                    print(f"error: {path}:{i}: not an event row",
                          file=sys.stderr)
                    raise SystemExit(2)
                rows.append(row)
    except OSError as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        raise SystemExit(2)
    if not rows:
        print(f"error: {path}: no events", file=sys.stderr)
        raise SystemExit(2)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="bottleneck attribution & timeline export over "
                    "repro.trace JSONL traces")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_rep = sub.add_parser("report", help="print the bottleneck report")
    p_rep.add_argument("trace", help="JSONL trace file")
    p_rep.add_argument("--json", action="store_true",
                       help="emit the machine-readable report")
    p_rep.add_argument("--window", type=float, default=None, metavar="S",
                       help="window width in seconds (default: span/48)")

    p_perf = sub.add_parser("perfetto",
                            help="export a Chrome-trace JSON timeline")
    p_perf.add_argument("trace", help="JSONL trace file")
    p_perf.add_argument("-o", "--out", required=True,
                        help="output .json path")

    args = ap.parse_args(argv)
    rows = _load(args.trace)

    if args.cmd == "report":
        from repro.obs.report import bottleneck_report, render_text
        rep = bottleneck_report(rows, window_s=args.window)
        if args.json:
            print(json.dumps(rep, indent=2, sort_keys=True))
        else:
            print(render_text(rep, title=args.trace))
        return 0

    from repro.obs.perfetto import to_chrome_trace
    trace = to_chrome_trace(rows)
    with open(args.out, "w") as f:
        json.dump(trace, f)
    n = len(trace["traceEvents"])
    print(f"wrote {args.out}: {n} trace events "
          f"from {len(rows)} log events")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
