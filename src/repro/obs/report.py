"""The bottleneck report: spans + windows + regimes folded into one verdict.

``bottleneck_report(events)`` returns a JSON-ready dict with three sections:

  * ``requests`` — the span-level latency decomposition aggregated over
    finished requests: per-phase total/mean/p95 seconds and each phase's
    share of summed end-to-end latency ("where the time went");
  * ``workers`` — per-worker dominant regime and regime-seconds;
  * ``regimes`` — fleet-level fraction of worker-seconds per regime, the
    dominant (non-idle) regime, and a one-line human verdict.

``render_text`` pretty-prints it for terminals; ``python -m repro.obs
report trace.jsonl`` wraps both. Everything derives from the event stream —
run it post-hoc on any JSONL trace, or over a recorded in-process log.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.core.metrics import latency_stats
from repro.obs.regimes import RegimeRules, attribute
from repro.obs.spans import PHASES, SpanFold, fold_spans
from repro.obs.windows import build_windows

_VERDICT = {
    "compute_bound": "iteration time is the limit — scale compute or "
                     "batch wider",
    "capacity_bound": "KV pressure throttles the fleet (the capacity "
                      "trap) — add KV (right-size TP), cap concurrency, "
                      "or shed load",
    "queue_bound": "backlog without KV or compute saturation — raise the "
                   "concurrency cap or admission/token budgets",
    "comms_bound": "migration / cold-start dominated — faster interconnect, "
                   "fewer migrations, or warmer pools",
    "idle": "fleet mostly idle — nothing to bottleneck",
}


def span_summary(fold: SpanFold) -> Dict:
    """Aggregate the latency decomposition over finished spans."""
    spans = fold.spans
    e2e = [s.total_s for s in spans]
    total_e2e = math.fsum(e2e)
    phases = {}
    for p in PHASES:
        vals = [s.phases[p] for s in spans]
        tot = math.fsum(vals)
        phases[p] = {
            "total_s": tot,
            "mean_s": tot / len(vals) if vals else 0.0,
            "p95_s": latency_stats(vals)["p95"],
            "frac_of_e2e": tot / total_e2e if total_e2e > 0 else 0.0,
        }
    return {
        "n_finished": len(spans),
        "n_unfinished": len(fold.open_spans),
        "n_migrated": sum(1 for s in spans if len(s.workers) > 1),
        "n_preempted": sum(1 for s in spans if s.n_preemptions > 0),
        "e2e_s": latency_stats(e2e),
        "phases": phases,
    }


def bottleneck_report(events, window_s: Optional[float] = None,
                      rules: Optional[RegimeRules] = None) -> Dict:
    """The full machine-readable report (see module docstring)."""
    rows = [e for e in events]
    rules = rules or RegimeRules()
    spans = fold_spans(rows)
    ws = build_windows(rows, window_s=window_s)
    reg = attribute(ws, rules)
    return {
        "n_events": len(rows),
        "t_min": ws.t_min,
        "t_max": ws.t_max,
        "window_s": ws.window_s,
        "n_workers": len(ws.by_worker),
        "requests": span_summary(spans),
        "workers": reg.per_worker,
        "regimes": {
            "worker_seconds": reg.worker_seconds,
            "fractions": reg.fractions,
            "busy_fractions": reg.busy_fractions,
            "dominant": reg.dominant,
            "verdict": _VERDICT[reg.dominant],
        },
    }


def regime_fractions(report: Dict) -> Dict:
    """The slice of the report ``ClusterMetrics.summary(regimes=...)``
    merges into a fleet summary."""
    r = report["regimes"]
    return {"fractions": r["fractions"], "busy_fractions":
            r["busy_fractions"], "dominant": r["dominant"]}


def _pct(x: float) -> str:
    return f"{100.0 * x:5.1f}%"


def render_text(rep: Dict, title: str = "") -> str:
    """Terminal rendering of ``bottleneck_report`` output."""
    lines: List[str] = []
    head = "repro.obs bottleneck report"
    if title:
        head += f" — {title}"
    lines.append(head)
    lines.append(f"  events {rep['n_events']}  workers {rep['n_workers']}  "
                 f"span [{rep['t_min']:.3f}, {rep['t_max']:.3f}]s  "
                 f"window {rep['window_s']:.3f}s")
    r = rep["regimes"]
    lines.append("  regime attribution (fraction of worker-seconds):")
    for name, frac in r["fractions"].items():
        busy = r["busy_fractions"].get(name)
        mark = " <== dominant" if (name == r["dominant"]
                                   and name != "idle") else ""
        extra = f"  ({_pct(busy)} of busy)" if busy is not None else ""
        lines.append(f"    {name:<15} {_pct(frac)}{extra}{mark}")
    lines.append(f"  verdict: {r['dominant']} — {r['verdict']}")
    q = rep["requests"]
    lines.append(f"  requests: {q['n_finished']} finished, "
                 f"{q['n_unfinished']} unfinished, "
                 f"{q['n_preempted']} preempted, {q['n_migrated']} migrated")
    lines.append("  latency decomposition (exact; fractions of summed e2e):")
    for p, st in q["phases"].items():
        lines.append(f"    {p:<17} {_pct(st['frac_of_e2e'])}  "
                     f"mean {st['mean_s']:.4f}s  p95 {st['p95_s']:.4f}s")
    lines.append("  per-worker dominant regime:")
    for name, info in rep["workers"].items():
        secs = info["seconds"]
        busy_s = sum(v for k, v in secs.items() if k != "idle")
        lines.append(f"    {name:<18} {info['dominant']:<15} "
                     f"busy {busy_s:.2f}s / idle {secs['idle']:.2f}s")
    return "\n".join(lines)


def attach(log, window_s: Optional[float] = None,
           rules: Optional[RegimeRules] = None):
    """Subscribe a recording tap to a live ``EventLog`` and return a
    zero-argument closure that builds the report once the run drains.

    This is the REP009-clean in-process hook: the tap is a pure subscriber
    (it only accumulates its own copy of the stream), so metrics stay
    bit-identical to an un-observed run."""
    rows: List = []
    log.subscribe(rows.append)
    return lambda: bottleneck_report(rows, window_s=window_s, rules=rules)
