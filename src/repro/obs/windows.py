"""Windowed per-worker time-series derived from the event stream.

The stream is sliced into fixed-width windows (``window_s``; ``None`` picks
``span / DEFAULT_N_WINDOWS`` from the trace itself, so the fold stays a pure
function of the stream) and each worker's events are folded into one
:class:`WindowStats` row per window it was alive in:

  * ``step`` events sample occupancy: running batch size, waiting-queue
    depth, KV utilisation and absolute page counts, the live concurrency
    cap (``max_seqs`` moves under the autotuner);
  * ``decode_step`` / ``prefill`` events count executed tokens exactly
    (independent of ``snapshot_every`` subsampling);
  * ``preempt`` / ``admit`` / ``resume`` events count scheduler churn;
  * ``kv_transfer`` + ``inject`` pairs attribute migration traffic — and
    the in-flight interval overlaps the *destination* worker's windows as
    ``transfer_overlap_s`` (time the adopter spent with KV inbound);
  * ``mint`` / ``join`` mark cold-start warming windows.

Everything here is computable from the stream alone (PR-9 extended the
``step`` payload precisely so this module needs no engine access), so the
same fold runs post-hoc over a JSONL trace or in-process as a subscriber.
Windows of two same-seed runs are identical because the streams are.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.spans import as_row

DEFAULT_N_WINDOWS = 48


@dataclasses.dataclass
class WindowStats:
    """One worker's activity inside one ``[t0, t1)`` window."""
    worker: str
    t0: float
    t1: float
    # occupancy samples (from ``step`` events; 0 samples => idle window)
    n_samples: int = 0
    running_mean: float = 0.0
    running_max: int = 0
    waiting_mean: float = 0.0
    waiting_max: int = 0
    kv_util_mean: float = 0.0
    kv_util_max: float = 0.0
    kv_pages_used_max: int = 0
    max_seqs: int = 0              # live concurrency cap (max over samples)
    # exact token counts (from decode_step / prefill events)
    decode_tokens: int = 0
    prefill_tokens: int = 0
    # scheduler churn
    preemptions: int = 0
    admits: int = 0
    resumes: int = 0
    # migration traffic
    migrations_out: int = 0        # ejects harvested off this worker
    migrations_in: int = 0         # injects adopted by this worker
    transfer_overlap_s: float = 0.0  # inbound KV in flight during the window
    warming: bool = False          # cold start (mint -> join) overlaps

    @property
    def width_s(self) -> float:
        return self.t1 - self.t0

    @property
    def decode_tok_s(self) -> float:
        return self.decode_tokens / self.width_s if self.width_s > 0 else 0.0

    @property
    def prefill_tok_s(self) -> float:
        return self.prefill_tokens / self.width_s if self.width_s > 0 else 0.0

    @property
    def preempt_rate(self) -> float:
        return self.preemptions / self.width_s if self.width_s > 0 else 0.0

    @property
    def busy(self) -> bool:
        return (self.decode_tokens > 0 or self.prefill_tokens > 0
                or self.running_max > 0 or self.waiting_max > 0)


@dataclasses.dataclass
class _Acc:
    """Raw per-(worker, window) accumulator before the mean division."""
    running_sum: float = 0.0
    waiting_sum: float = 0.0
    kv_util_sum: float = 0.0
    stats: WindowStats = None


class WindowSet:
    """All workers' windows plus the trace-wide frame they were cut from."""

    def __init__(self, t_min: float, t_max: float, window_s: float,
                 by_worker: Dict[str, List[WindowStats]]):
        self.t_min = t_min
        self.t_max = t_max
        self.window_s = window_s
        self.by_worker = by_worker

    @property
    def workers(self) -> List[str]:
        return list(self.by_worker)

    def all_windows(self) -> List[WindowStats]:
        return [w for ws in self.by_worker.values() for w in ws]


def _frame(events) -> Tuple[float, float]:
    t_min = t_max = None
    for ev in events:
        t = as_row(ev)["t"]
        t_min = t if t_min is None else min(t_min, t)
        t_max = t if t_max is None else max(t_max, t)
    return (t_min or 0.0), (t_max or 0.0)


def build_windows(events, window_s: Optional[float] = None) -> WindowSet:
    """Cut the stream into windows and fold per-worker stats (post-hoc; the
    events are iterated twice, so pass a list, not a generator)."""
    rows = [as_row(ev) for ev in events]
    t_min, t_max = _frame(rows)
    if window_s is None:
        span = max(t_max - t_min, 1e-9)
        window_s = span / DEFAULT_N_WINDOWS
    window_s = max(window_s, 1e-9)

    accs: Dict[Tuple[str, int], _Acc] = {}
    # worker lifecycle intervals for warming overlap: name -> [mint, join]
    warm_start: Dict[str, float] = {}
    warm_end: Dict[str, float] = {}
    # in-flight transfers: rid -> (t_eject,); closed by inject with dst
    pending: Dict[int, float] = {}
    transfers: List[Tuple[str, float, float]] = []   # (dst, t0, t1)

    def acc_i(worker: str, i: int) -> _Acc:
        key = (worker, i)
        a = accs.get(key)
        if a is None:
            a = _Acc(stats=WindowStats(
                worker=worker, t0=t_min + i * window_s,
                t1=t_min + (i + 1) * window_s))
            accs[key] = a
        return a

    def acc(worker: str, t: float) -> _Acc:
        return acc_i(worker, int((t - t_min) / window_s))

    for row in rows:
        kind, t, w = row["kind"], row["t"], row["worker"]
        p = row["payload"]
        if kind == "step":
            a = acc(w, t)
            s = a.stats
            s.n_samples += 1
            a.running_sum += p["running"]
            a.waiting_sum += p["waiting"]
            a.kv_util_sum += p["kv_util"]
            s.running_max = max(s.running_max, p["running"])
            s.waiting_max = max(s.waiting_max, p["waiting"])
            s.kv_util_max = max(s.kv_util_max, p["kv_util"])
            s.kv_pages_used_max = max(s.kv_pages_used_max,
                                      p.get("kv_pages_used", 0))
            s.max_seqs = max(s.max_seqs, p.get("max_seqs", 0))
        elif kind == "decode_step":
            acc(w, t).stats.decode_tokens += len(p["rids"])
        elif kind == "prefill":
            acc(w, t).stats.prefill_tokens += p["chunk"]
        elif kind == "preempt":
            acc(w, t).stats.preemptions += 1
        elif kind == "admit":
            acc(w, t).stats.admits += 1
        elif kind == "resume":
            acc(w, t).stats.resumes += 1
        elif kind == "eject":
            acc(w, t).stats.migrations_out += 1
        elif kind == "kv_transfer":
            pending[row["rid"]] = t
        elif kind == "inject":
            acc(w, t).stats.migrations_in += 1
            t0 = pending.pop(row["rid"], None)
            if t0 is not None:
                transfers.append((w, t0, t))
        elif kind == "mint":
            warm_start[w] = t
        elif kind == "join":
            warm_end[w] = t

    # inbound-transfer overlap: spread each (dst, t0, t1) interval over the
    # destination's windows it intersects
    for dst, a, b in transfers:
        i0 = int((a - t_min) / window_s)
        i1 = int((b - t_min) / window_s)
        for i in range(i0, i1 + 1):
            w0 = t_min + i * window_s
            ov = min(b, w0 + window_s) - max(a, w0)
            if ov > 0:
                acc_i(dst, i).stats.transfer_overlap_s += ov

    # warming overlap: mark the minted worker's windows inside
    # [mint, join) — cold start is comms/provisioning, not serving
    for name, w0 in warm_start.items():
        w1 = warm_end.get(name, t_max)
        i0 = int((w0 - t_min) / window_s)
        i1 = int((max(w1 - 1e-12, w0) - t_min) / window_s)
        for i in range(i0, i1 + 1):
            acc_i(name, i).stats.warming = True

    by_worker: Dict[str, List[WindowStats]] = {}
    for (worker, _i), a in sorted(accs.items()):
        s = a.stats
        if s.n_samples:
            s.running_mean = a.running_sum / s.n_samples
            s.waiting_mean = a.waiting_sum / s.n_samples
            s.kv_util_mean = a.kv_util_sum / s.n_samples
        by_worker.setdefault(worker, []).append(s)
    return WindowSet(t_min, t_max, window_s, by_worker)
