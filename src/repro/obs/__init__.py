"""repro.obs — bottleneck attribution, request spans, and timeline export.

A pure consumer of the ``repro.trace`` event spine (REP009-clean: fold a
recorded stream post-hoc, or subscribe via ``attach`` — never mutates
engine/metrics state). Three layers:

  * :mod:`repro.obs.spans` — exact per-request latency decomposition;
  * :mod:`repro.obs.windows` — windowed per-worker time-series;
  * :mod:`repro.obs.regimes` — bottleneck regime classification
    (compute/capacity/queue/comms-bound) over worker-windows;

surfaced by :func:`bottleneck_report` / ``python -m repro.obs report`` and
the Perfetto export :func:`to_chrome_trace` / ``python -m repro.obs
perfetto``. See docs/obs.md.
"""
from repro.obs.perfetto import to_chrome_trace
from repro.obs.regimes import (REGIMES, RegimeReport, RegimeRules,
                               WindowVerdict, attribute, classify)
from repro.obs.report import (attach, bottleneck_report, regime_fractions,
                              render_text, span_summary)
from repro.obs.spans import PHASES, Segment, Span, SpanFold, fold_spans
from repro.obs.windows import (DEFAULT_N_WINDOWS, WindowSet, WindowStats,
                               build_windows)

__all__ = [
    "PHASES", "Segment", "Span", "SpanFold", "fold_spans",
    "DEFAULT_N_WINDOWS", "WindowSet", "WindowStats", "build_windows",
    "REGIMES", "RegimeReport", "RegimeRules", "WindowVerdict",
    "attribute", "classify",
    "attach", "bottleneck_report", "regime_fractions", "render_text",
    "span_summary", "to_chrome_trace",
]
