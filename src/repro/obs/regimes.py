"""Regime classification: label each worker-window with the bottleneck that
governed it — the paper's diagnostic core made machine-readable.

The paper's claim is that reasoning workloads push serving out of the
Compute-Bound regime into a *Capacity-Bound* one where KV pressure (not
FLOPs) throttles throughput, and that the right mitigation depends on which
regime dominates. Decision rules, checked in order (first match wins):

  1. ``comms_bound`` / cold start — the window overlaps the worker's
     mint->join warming interval: it is paying weight-load, not serving.
  2. ``idle`` — no samples, no tokens, no queue. (If inbound KV transfers
     were in flight during an otherwise idle window, it is ``comms_bound``:
     the worker is starved by the migration wire, not by lack of demand.)
  3. ``capacity_bound`` / preemption storm — any preemption in the window.
     Preemption only happens when the page pool is exhausted mid-decode, so
     its presence is *direct* evidence of KV pressure (Obs 1: recompute
     waste collapses goodput past the capacity knee).
  4. ``capacity_bound`` / KV-throttled admission — peak KV utilisation at or
     above ``kv_saturated`` while work queues: the pool, not the batch cap,
     is what blocks admission.
  5. ``comms_bound`` / migration-dominated — inbound KV transfer in flight
     for at least ``comms_frac`` of the window while KV and preemptions are
     quiet: the wire (kv_transfer_time) gates progress.
  6. ``queue_bound`` — a backlog waits while the running batch sits below
     ``cap_frac`` of the live concurrency cap and KV has headroom: admission
     pacing / token-budget / burst arrival limits, not compute or capacity.
  7. ``compute_bound`` — the worker is busy (tokens flowed or the batch ran
     at/near its cap) with none of the above: iteration time is the limit.

Thresholds live in :class:`RegimeRules` so sweeps can calibrate; defaults
match the paper's testbed behaviour (capacity_trap at high concurrency
classifies ``capacity_bound``, at low concurrency ``compute_bound`` —
asserted in tests and in the ``obs-smoke`` CI job).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.obs.windows import WindowSet, WindowStats

REGIMES = ("compute_bound", "capacity_bound", "queue_bound", "comms_bound",
           "idle")


@dataclasses.dataclass(frozen=True)
class RegimeRules:
    kv_saturated: float = 0.90   # KV util at/above this = pool pressure
    queue_min: float = 1.0       # mean waiting depth that counts as backlog
    cap_frac: float = 0.90       # running/max_seqs below this = cap headroom
    comms_frac: float = 0.50     # transfer-overlap fraction that dominates


@dataclasses.dataclass(frozen=True)
class WindowVerdict:
    window: WindowStats
    regime: str
    reason: str


def classify(w: WindowStats, rules: RegimeRules = RegimeRules()
             ) -> Tuple[str, str]:
    """(regime, reason) for one worker-window — the decision table above."""
    if w.warming:
        return "comms_bound", "cold_start"
    if not w.busy:
        if w.transfer_overlap_s > 0:
            return "comms_bound", "starved_awaiting_kv_transfer"
        return "idle", "no_work"
    if w.preemptions > 0:
        return "capacity_bound", "preemption_storm"
    if w.kv_util_max >= rules.kv_saturated and w.waiting_mean > 0:
        return "capacity_bound", "kv_throttled_admission"
    if (w.width_s > 0 and w.transfer_overlap_s / w.width_s >= rules.comms_frac
            and w.kv_util_max < rules.kv_saturated):
        return "comms_bound", "migration_dominated"
    if (w.waiting_mean >= rules.queue_min and w.max_seqs > 0
            and w.running_max < rules.cap_frac * w.max_seqs
            and w.kv_util_max < rules.kv_saturated):
        return "queue_bound", "backlog_below_concurrency_cap"
    return "compute_bound", "busy_no_kv_pressure"


@dataclasses.dataclass
class RegimeReport:
    """Fleet-level attribution: worker-seconds spent in each regime."""
    verdicts: List[WindowVerdict]
    worker_seconds: Dict[str, float]          # regime -> seconds
    fractions: Dict[str, float]               # regime -> share of total
    busy_fractions: Dict[str, float]          # share excluding idle
    dominant: str                             # busiest non-idle regime
    per_worker: Dict[str, Dict]               # worker -> {dominant, seconds}

    def to_dict(self) -> Dict:
        return {
            "worker_seconds": dict(self.worker_seconds),
            "fractions": dict(self.fractions),
            "busy_fractions": dict(self.busy_fractions),
            "dominant": self.dominant,
            "per_worker": {k: dict(v) for k, v in self.per_worker.items()},
        }


def attribute(ws: WindowSet, rules: RegimeRules = RegimeRules()
              ) -> RegimeReport:
    """Classify every worker-window and integrate into fleet fractions.

    Each window contributes its width in worker-seconds to its regime (the
    same mint->drain accounting ``ClusterMetrics.worker_seconds`` uses, at
    window granularity); ``dominant`` is the regime holding the largest
    share of non-idle worker-seconds — the fleet's bottleneck verdict."""
    verdicts: List[WindowVerdict] = []
    seconds = {r: 0.0 for r in REGIMES}
    per_worker: Dict[str, Dict] = {}
    for worker, windows in ws.by_worker.items():
        wsec = {r: 0.0 for r in REGIMES}
        for w in windows:
            regime, reason = classify(w, rules)
            verdicts.append(WindowVerdict(w, regime, reason))
            seconds[regime] += w.width_s
            wsec[regime] += w.width_s
        busy = {r: s for r, s in wsec.items() if r != "idle" and s > 0}
        per_worker[worker] = {
            "dominant": max(busy, key=busy.get) if busy else "idle",
            "seconds": wsec,
        }
    total = sum(seconds.values())
    busy_total = total - seconds["idle"]
    fractions = {r: (s / total if total > 0 else 0.0)
                 for r, s in seconds.items()}
    busy_fractions = {r: (s / busy_total if busy_total > 0 else 0.0)
                      for r, s in seconds.items() if r != "idle"}
    candidates = {r: s for r, s in seconds.items() if r != "idle" and s > 0}
    dominant = max(candidates, key=candidates.get) if candidates else "idle"
    return RegimeReport(verdicts=verdicts, worker_seconds=seconds,
                        fractions=fractions, busy_fractions=busy_fractions,
                        dominant=dominant, per_worker=per_worker)
