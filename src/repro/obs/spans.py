"""Request spans: fold a ``repro.trace`` event stream into an exact
per-request latency decomposition.

Every request's end-to-end latency is partitioned into the six phases of its
lifecycle (the span taxonomy of docs/obs.md):

  queue_wait        arrival -> first admission (the request sits in the
                    waiting queue; KV-throttled admission shows up here)
  prefill           admission -> first decode participation (chunked prompt
                    processing, including the completing chunk's token)
  decode            steady-state token generation
  preempted_stall   preempt -> resume (KV pages evicted, request requeued)
  recompute_resume  resume -> decode re-entry (the regenerated prefix is
                    re-prefilled — pure waste, the cost of recompute-mode
                    preemption)
  kv_transfer       eject -> inject (disaggregated migration: modeled wire
                    time plus any wait for a decode slot)

**Exactness guarantee.** Phase boundaries are event timestamps; durations
are accumulated as exact rationals (``fractions.Fraction`` of the IEEE-754
doubles), so the per-span sum telescopes *exactly* to
``t_finished - arrival`` with zero floating-point drift: ``Span.total_s``
(the correctly-rounded float of the exact sum) equals the float subtraction
``t_finished - arrival`` to the last ulp, because IEEE subtraction is itself
correctly rounded. Tests assert both identities on every finished request of
colocated, disaggregated and autoscaled runs.

The fold is a pure stream consumer (REP009-clean): subscribe ``on_event`` to
a live ``EventLog``, or feed it recorded ``Event`` objects / JSONL dict rows
post-hoc — it never touches engine or metrics state.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from typing import Any, Dict, List, Optional, Union

PHASES = ("queue_wait", "prefill", "decode", "preempted_stall",
          "recompute_resume", "kv_transfer")


def as_row(ev: Union[Any, Dict[str, Any]]) -> Dict[str, Any]:
    """Normalise an ``Event`` object or a loaded JSONL dict to one shape."""
    if isinstance(ev, dict):
        return ev
    return ev.to_dict()


@dataclasses.dataclass(frozen=True)
class Segment:
    """One contiguous interval of a span, attributed to a phase and the
    worker the request occupied during it (the Perfetto Gantt row source)."""
    phase: str
    t0: float
    t1: float
    worker: str


@dataclasses.dataclass
class Span:
    """One request's folded lifecycle."""
    rid: int
    arrival: float
    slo_class: str = ""
    isl: int = 0
    t_finished: Optional[float] = None
    generated: int = 0
    n_preemptions: int = 0
    workers: List[str] = dataclasses.field(default_factory=list)
    segments: List[Segment] = dataclasses.field(default_factory=list)
    # exact per-phase durations (Fractions of the boundary doubles)
    phase_fracs: Dict[str, Fraction] = dataclasses.field(
        default_factory=lambda: {p: Fraction(0) for p in PHASES})

    @property
    def finished(self) -> bool:
        return self.t_finished is not None

    @property
    def phases(self) -> Dict[str, float]:
        """Per-phase seconds (floats, for reporting). Summing these floats
        can drift by ulps; use ``total_s`` for the exact total."""
        return {p: float(f) for p, f in self.phase_fracs.items()}

    @property
    def exact_total(self) -> Fraction:
        """Exact rational sum of the phase durations — telescopes to
        ``Fraction(t_finished) - Fraction(arrival)`` by construction."""
        return sum(self.phase_fracs.values(), Fraction(0))

    @property
    def total_s(self) -> float:
        """The exact total, correctly rounded to a double: equals the float
        subtraction ``t_finished - arrival`` to the last ulp."""
        return float(self.exact_total)


class _OpenSpan:
    __slots__ = ("span", "phase", "t_cur", "worker")

    def __init__(self, span: Span, t0: float, worker: str):
        self.span = span
        self.phase = "queue_wait"
        self.t_cur = t0
        self.worker = worker


class SpanFold:
    """Stream subscriber folding per-rid events into :class:`Span` rows.

    ``spans`` holds finished requests in finish order; ``open_spans`` the
    still-in-flight ones (a truncated trace leaves them open — the report
    counts them as unfinished, never silently drops them). A rid reused
    after a ``finish`` (concatenated benchmark traces) starts a new span.
    """

    def __init__(self):
        self.spans: List[Span] = []
        self._open: Dict[int, _OpenSpan] = {}

    @property
    def open_spans(self) -> List[Span]:
        return [o.span for o in self._open.values()]

    # ------------------------------------------------------------- the fold
    def on_event(self, ev):
        row = as_row(ev)
        kind = row["kind"]
        if kind == "decode_step":
            t = row["t"]
            for rid in row["payload"]["rids"]:
                o = self._open.get(rid)
                if o is not None and o.phase != "decode":
                    self._transition(o, t, "decode", row["worker"])
            return
        rid = row.get("rid")
        if rid is None:
            return
        t, worker, payload = row["t"], row["worker"], row["payload"]
        if kind == "arrival":
            arr = payload.get("arrival", t)
            span = Span(rid=rid, arrival=arr,
                        slo_class=payload.get("slo_class", ""),
                        isl=payload.get("isl", 0), workers=[worker])
            self._open[rid] = _OpenSpan(span, arr, worker)
        elif kind == "admit":
            self._on(rid, t, "prefill", worker)
        elif kind == "resume":
            self._on(rid, t, "recompute_resume", worker)
        elif kind == "preempt":
            self._on(rid, t, "preempted_stall", worker)
            o = self._open.get(rid)
            if o is not None:
                o.span.n_preemptions += 1
        elif kind == "eject":
            self._on(rid, t, "kv_transfer", worker)
        elif kind == "inject":
            # prefill-complete by construction: the adopter decodes next
            self._on(rid, t, "decode", worker)
            o = self._open.get(rid)
            if o is not None and worker not in o.span.workers:
                o.span.workers.append(worker)
        elif kind == "finish":
            o = self._open.pop(rid, None)
            if o is None:
                return
            self._close(o, t)
            o.span.t_finished = t
            o.span.generated = payload.get("generated", 0)
            self.spans.append(o.span)

    # ------------------------------------------------------------ internals
    def _on(self, rid: int, t: float, phase: str, worker: str):
        o = self._open.get(rid)
        if o is not None:
            self._transition(o, t, phase, worker)

    def _transition(self, o: _OpenSpan, t: float, phase: str, worker: str):
        self._close(o, t)
        o.phase = phase
        o.t_cur = t
        o.worker = worker

    def _close(self, o: _OpenSpan, t: float):
        o.span.phase_fracs[o.phase] += Fraction(t) - Fraction(o.t_cur)
        if t > o.t_cur:      # zero-width segments add nothing to the Gantt
            o.span.segments.append(
                Segment(phase=o.phase, t0=o.t_cur, t1=t, worker=o.worker))


def fold_spans(events) -> SpanFold:
    """Post-hoc fold over recorded events (``Event`` objects or JSONL dict
    rows)."""
    fold = SpanFold()
    for ev in events:
        fold.on_event(ev)
    return fold
