"""Chrome-trace (Perfetto / ``chrome://tracing``) export of a recorded run.

``to_chrome_trace(events)`` renders the stream as a standard trace-event
JSON object (``{"traceEvents": [...], "displayTimeUnit": "ms"}``):

  * one *process* per worker (pid = 1 + worker index, named via ``ph:"M"``
    ``process_name`` metadata), so each worker gets its own track group;
  * one *thread* per request (tid = rid) carrying the request's span
    segments as ``ph:"X"`` complete events — a per-worker Gantt chart of
    queue_wait / prefill / decode / preempted_stall / recompute_resume /
    kv_transfer, colored by phase name;
  * ``ph:"C"`` counter rows per worker sampled from ``step`` events:
    KV pages (used/free stacked), running batch + waiting queue depth.

Timestamps are microseconds (the sim clock's seconds * 1e6), durations
likewise; a segment spanning a migration is emitted on the worker that
owned the request during that interval, so hand-offs read left-to-right
across process tracks. Load the output directly in ``ui.perfetto.dev``.
"""
from __future__ import annotations

from typing import Any, Dict, List

from repro.obs.spans import as_row, fold_spans

_US = 1e6


def _pid_table(rows: List[Dict[str, Any]]) -> Dict[str, int]:
    """worker name -> pid, in order of first appearance in the stream."""
    pids: Dict[str, int] = {}
    for row in rows:
        w = row["worker"]
        if w and w not in pids:
            pids[w] = 1 + len(pids)
    return pids


def to_chrome_trace(events) -> Dict[str, Any]:
    rows = [as_row(ev) for ev in events]
    pids = _pid_table(rows)
    out: List[Dict[str, Any]] = []

    for w, pid in pids.items():
        out.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                    "args": {"name": f"worker:{w}"}})

    # ---- request Gantt: one thread per rid, span segments as X events
    fold = fold_spans(rows)
    named: set = set()
    for span in fold.spans + fold.open_spans:
        for seg in span.segments:
            pid = pids.get(seg.worker)
            if pid is None:
                continue
            key = (pid, span.rid)
            if key not in named:
                named.add(key)
                out.append({"ph": "M", "name": "thread_name", "pid": pid,
                            "tid": span.rid,
                            "args": {"name": f"req {span.rid}"}})
            out.append({
                "ph": "X", "name": seg.phase, "cat": "request",
                "pid": pid, "tid": span.rid,
                "ts": seg.t0 * _US, "dur": (seg.t1 - seg.t0) * _US,
                "args": {"rid": span.rid, "worker": seg.worker},
            })

    # ---- per-worker counters from step samples
    for row in rows:
        if row["kind"] != "step":
            continue
        pid = pids.get(row["worker"])
        if pid is None:
            continue
        p, ts = row["payload"], row["t"] * _US
        out.append({"ph": "C", "name": "kv_pages", "cat": "kv",
                    "pid": pid, "tid": 0, "ts": ts,
                    "args": {"used": p.get("kv_pages_used", 0),
                             "free": p.get("kv_pages_free", 0)}})
        out.append({"ph": "C", "name": "batch", "cat": "sched",
                    "pid": pid, "tid": 0, "ts": ts,
                    "args": {"running": p["running"],
                             "waiting": p["waiting"]}})
    return {"traceEvents": out, "displayTimeUnit": "ms"}
