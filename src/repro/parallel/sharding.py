"""Logical-axis sharding: every parameter/activation carries logical axis
names; a rule table maps them onto mesh axes.

Baseline rules (paper-faithful TP mapping, re-targeted to TPU):
  * weights: FSDP over "data" on the d_model/d_ff contracting axes,
    TP over "model" on heads / mlp / experts / vocab.
  * activations: batch over ("pod","data"); model-axis sharding follows from
    the weights via GSPMD.
  * multi-pod: params replicated across "pod" (gradients all-reduce over pod);
    batch additionally sharded over "pod".

Head padding: TP requires the (q-)head axis divisible by the model-axis size.
``padded_heads`` computes (hp, kvp) such that hp % tp == 0, kvp % tp == 0,
hp % kvp == 0 and (GQA case) kvp % n_kv == 0 — padded q-head slots are
zero-initialised (mathematically inert), replicated kv slots are tiled copies
(exact math; serving-only — the train path shards kv projections on the
contracting axis instead and keeps true kv shapes).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """jax.shard_map across jax versions: top-level `jax.shard_map(check_vma=)`
    (>= 0.6) vs `jax.experimental.shard_map.shard_map(check_rep=)`."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    """Everything model code needs to know about the device layout."""
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)   # ("pod","data") for multi-pod
    model_axis: str = "model"
    fsdp_axis: Optional[str] = "data"         # None -> replicate weights over data
    remat: str = "none"                       # none | full
    kv_cache_dtype: Any = None                # default bf16; int8 is a §Perf lever
    moe_dispatch: str = "auto"                # auto | split | replicated
    rules_override: Optional[Dict[str, Any]] = None
    # ---- §Perf hillclimb levers (EXPERIMENTS.md §Perf) ----------------------
    decode_unroll: bool = False     # unrolled decode layers + in-place scatter
    serve_2d_tp: bool = False       # contract-dim TP over "data" (no FSDP
                                    # weight gathers in decode; Pope et al.)
    seq_parallel_norm: bool = False  # Megatron-SP residual stream (prefill)
    moe_ff_shard: bool = False      # expert-ffn dim sharded over "data"
                                    # (replaces the expert FSDP gather)
    seq_shard_decode: bool = False  # unpadded kv heads; cache seq over "model"
    train_kv_2d: bool = False       # train kv-proj d_model sharded over BOTH
                                    # axes (partial+psum kills the 16x
                                    # replicated kv compute under TP)

    @property
    def tp(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def dp(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    def rules(self) -> Dict[str, Any]:
        r = dict(DEFAULT_RULES)
        r["batch"] = self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]
        if self.fsdp_axis is None:
            for k in ("embed", "mlp_in", "expert_in"):
                r[k] = None
        else:
            r["embed"] = self.fsdp_axis
        if self.serve_2d_tp:
            r["act_d"] = self.fsdp_axis or "data"
        if self.seq_parallel_norm:
            r["act_seq"] = self.model_axis
        if self.moe_ff_shard:
            r["expert_ff"] = self.fsdp_axis or "data"
        r["embed_kv"] = ((self.fsdp_axis or "data", self.model_axis)
                         if self.train_kv_2d else r["embed"])
        if self.seq_shard_decode:
            r["cache_seq"] = self.model_axis
            r["cache_kv"] = None
        if self.rules_override:
            r.update(self.rules_override)
        return r

    def spec(self, *logical_axes: Optional[str]) -> P:
        rules = self.rules()
        return P(*[rules.get(a) if a is not None else None for a in logical_axes])

    def shard(self, x, *logical_axes):
        """Constrain activation sharding (no-op without a mesh)."""
        if self.mesh is None or self.mesh.size == 1:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*logical_axes)))


# logical axis -> mesh axis (None = replicated)
DEFAULT_RULES: Dict[str, Any] = {
    "batch": "data",
    "seq": None,
    "embed": "data",        # FSDP: weight d_model axis
    "vocab": "model",       # embedding table vocab axis (TP)
    "heads": "model",       # padded q-head axis
    "kv_heads": "model",    # padded kv-head axis (serve layout)
    "kv_heads_exact": None, # unpadded kv heads (train layout: replicated acts)
    "d_tp": "model",        # untied embedding-table d_model axis (TP)
    "head_dim": None,
    "mlp": "model",         # d_ff axis
    "mlp_in": "data",       # FSDP on the w_down d_ff input axis
    "expert": "model",      # expert-parallel axis
    "expert_in": "data",    # FSDP inside each expert's d_model axis
    "expert_ff": None,      # §Perf moe_ff_shard flips this to "data"
    "layers": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv_ch": "model",
    "lstm_vdim": "model",   # mLSTM value head_dim sharding
    "mla_rank": None,
    "cache_batch": "data",
    "cache_seq": None,      # §Perf flips this to "data"/"model" for seq-sharded KV
    "cache_kv": "model",
    "act_d": None,          # §Perf serve_2d_tp: activation d_model axis
    "act_seq": None,        # §Perf seq_parallel_norm: residual seq axis
    "embed_kv": "data",     # kv-proj d_model axis (train_kv_2d -> 2D tuple)
}

HOST_1D = None  # sentinel for "no mesh"


def single_device_ctx() -> ParallelContext:
    return ParallelContext(mesh=None)


def make_test_mesh(data: int = 1, model: int = 1) -> Mesh:
    return jax.make_mesh((data, model), ("data", "model"))


def padded_heads(n_heads: int, n_kv: int, tp: int) -> Tuple[int, int]:
    """(hp, kvp): padded q/kv head counts for a TP degree (see module doc)."""
    if tp <= 1:
        return n_heads, n_kv
    hp = -(-n_heads // tp) * tp
    if n_kv >= n_heads:                      # MHA: 1:1, zero-pad both
        return hp, hp
    kvp = tp
    while not (hp % kvp == 0 and kvp % n_kv == 0 and kvp >= n_kv):
        kvp += tp
        if kvp > hp:                         # fall back: widen hp to lcm
            hp = abs(hp * n_kv) // math.gcd(hp, n_kv)
            hp = -(-hp // tp) * tp
            kvp = tp
    return hp, kvp


def q_to_orig(hp: int, kvp: int, n_heads: int, n_kv: int) -> np.ndarray:
    """Map padded q slot -> original q head (or -1 for inert pad slots).

    Padded q slots are grouped contiguously by padded kv slot (g' = hp//kvp
    per slot); padded kv slot s replicates original kv head s // (kvp//n_kv)
    (identity + zero-pad in the MHA case). Original q heads of kv group k are
    distributed over that group's replica slots in order.
    """
    out = -np.ones(hp, dtype=np.int64)
    gp = hp // kvp
    if n_kv >= n_heads:                      # MHA identity
        out[:n_heads] = np.arange(n_heads)
        return out
    r = kvp // n_kv
    g = n_heads // n_kv
    for k in range(n_kv):
        orig = list(range(k * g, (k + 1) * g))
        slots = [s * gp + j for s in range(k * r, (k + 1) * r) for j in range(gp)]
        for slot, oq in zip(slots, orig):
            out[slot] = oq
    return out


def kv_to_orig(kvp: int, n_heads: int, n_kv: int) -> np.ndarray:
    """Map padded kv slot -> original kv head (or -1 for zero-pad in MHA)."""
    out = -np.ones(kvp, dtype=np.int64)
    if n_kv >= n_heads:
        out[:n_kv] = np.arange(n_kv)
        return out
    r = kvp // n_kv
    out[:] = np.arange(kvp) // r
    return out
