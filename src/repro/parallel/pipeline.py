"""Pipeline parallelism as a jax-native shard_map schedule.

GPipe-style forward: layers are grouped into `n_stages` stages; stage s lives
on mesh axis "stage" coordinate s. Micro-batches stream through via
lax.ppermute; the schedule runs n_micro + n_stages - 1 ticks and each stage
computes under a validity mask (bubbles execute masked work — the same bubble
fraction (p-1)/(m+p-1) the paper's §II-D/§V-C analyses, here made explicit).

Differentiable end-to-end (grad flows through ppermute), so the same schedule
serves training; tests/test_pipeline.py checks exact equivalence with the
single-device stack.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import shard_map


def pipeline_forward(stage_fn: Callable, params_stacked, x, *, mesh: Mesh,
                     n_micro: int, stage_axis: str = "stage"):
    """x (B, ...) split into n_micro micro-batches along axis 0.

    stage_fn(stage_params, micro_x) -> micro_y, applied by every stage
    (stage_params = params_stacked[s] on stage s).
    params_stacked: pytree with leading axis n_stages.
    Returns y (B, ...) = stage_{p-1}(... stage_0(x)).
    """
    n_stages = mesh.shape[stage_axis]
    B = x.shape[0]
    assert B % n_micro == 0, f"batch {B} % n_micro {n_micro}"
    mb = B // n_micro

    def body(params_local, x_local):
        # params_local: stage slice (leading axis 1); x_local: full batch on
        # stage 0 semantics (we broadcast the input and mask by stage)
        params_here = jax.tree_util.tree_map(lambda a: a[0], params_local)
        s = jax.lax.axis_index(stage_axis)
        micros = x_local.reshape(n_micro, mb, *x_local.shape[1:])
        ticks = n_micro + n_stages - 1
        carry = jnp.zeros_like(stage_fn(params_here, micros[0]))
        outs = jnp.zeros((n_micro, *carry.shape), carry.dtype)

        def tick(t, state):
            carry, outs = state
            # stage 0 ingests micro-batch t (if in range); others take the
            # permuted output of their predecessor
            feed = jnp.where(t < n_micro, micros[jnp.minimum(t, n_micro - 1)],
                             jnp.zeros_like(micros[0]))
            inp = jnp.where(s == 0, feed.astype(carry.dtype), carry)
            out = stage_fn(params_here, inp)
            # valid iff this stage is currently processing micro t-s
            valid = jnp.logical_and(t - s >= 0, t - s < n_micro)
            out = jnp.where(valid, out, jnp.zeros_like(out))
            # last stage records its finished micro-batch
            mi = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            take = jnp.logical_and(s == n_stages - 1, valid)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(take, out, outs[mi]), mi, axis=0)
            # hand off to the next stage
            carry = jax.lax.ppermute(
                out, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return carry, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (carry, outs))
        # only the last stage holds real outputs; broadcast them to all
        outs = jax.lax.psum(
            jnp.where(s == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs.reshape(B, *outs.shape[2:])

    in_specs = (P(stage_axis), P())
    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check=False)(params_stacked, x)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
