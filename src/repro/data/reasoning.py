"""Synthetic Natural-Reasoning workload (paper §III-B, Fig 1).

Matches the paper's published distribution stats:
  * ISL: 77% of prompts 50-150 tokens, very few > 300
  * OSL: 45% of responses exceed 5000 tokens (heavy-tailed reasoning traces)
plus a "chat" profile (OSL ~ 500) for the reasoning-vs-chat contrast.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str = "natural_reasoning"
    isl_mode: float = 95.0
    isl_sigma: float = 0.45
    isl_max: int = 1024
    osl_median: float = 4200.0
    osl_sigma: float = 1.05
    osl_max: int = 32768

    def chatty(self) -> "WorkloadSpec":
        return dataclasses.replace(self, name="chat", osl_median=350.0,
                                   osl_sigma=0.7, osl_max=2048)

    def long_context(self) -> "WorkloadSpec":
        """RAG/agentic profile: kilotoken prompts, same reasoning-heavy OSL —
        the regime where prefill chunks materially stall colocated decode
        (§III phase divergence)."""
        return dataclasses.replace(self, name="long_context_reasoning",
                                   isl_mode=1200.0, isl_sigma=0.5,
                                   isl_max=6000)


CHAT = WorkloadSpec().chatty()
REASONING = WorkloadSpec()
LONG_REASONING = WorkloadSpec().long_context()


def sample(spec: WorkloadSpec, n: int, seed: int = 0
           ) -> List[Tuple[int, int]]:
    """Returns [(isl, osl)] * n."""
    rng = np.random.default_rng(seed)
    isl = np.clip(rng.lognormal(np.log(spec.isl_mode), spec.isl_sigma, n),
                  8, spec.isl_max).astype(int)
    osl = np.clip(rng.lognormal(np.log(spec.osl_median), spec.osl_sigma, n),
                  16, spec.osl_max).astype(int)
    return list(zip(isl.tolist(), osl.tolist()))


def profile(spec: WorkloadSpec, n: int = 100_000, seed: int = 0):
    """Distribution stats mirroring the paper's Fig 1 analysis."""
    s = sample(spec, n, seed)
    isl = np.array([a for a, _ in s])
    osl = np.array([b for _, b in s])
    return {
        "isl_50_150": float(((isl >= 50) & (isl <= 150)).mean()),
        "isl_gt_300": float((isl > 300).mean()),
        "osl_gt_5000": float((osl > 5000).mean()),
        "mean_isl": float(isl.mean()),
        "mean_osl": float(osl.mean()),
    }
