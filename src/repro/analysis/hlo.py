"""HLO-text cost analyzer for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers program under-reports FLOPs/bytes by the layer count. This
module parses ``compiled.as_text()`` and computes, per device:

  * flops            — dot ops: 2 * prod(result_dims) * prod(contracting_dims),
                       multiplied through while-loop known trip counts
  * hbm_bytes        — operand + result bytes of dots / fusions / copies /
                       slices / gathers / collectives (a consistent
                       HBM-traffic proxy at XLA's fusion granularity)
  * collectives      — per op-kind payload bytes (operand sizes), group sizes,
                       and ICI wire-bytes using ring terms:
                       all-reduce 2(n-1)/n, all-gather/reduce-scatter (n-1)/n,
                       all-to-all (n-1)/n, collective-permute 1x.

The parser resolves nested whiles / calls / fusions recursively with
memoisation, using the ``known_trip_count`` XLA records in backend_config
(falling back to constants compared in the loop condition).
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\s*{\s*"n":\s*"(\d+)"')


def _parse_op_line(line: str):
    """'%name = TYPE opcode(rest' -> (name, type, opcode, rest) or None."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") and not s[:1].isalpha():
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].strip().lstrip("%")
    rhs = s[eq + 3:]
    # result type: balanced paren block (tuple) or a single token
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        rtype, rest = rhs[:i + 1], rhs[i + 1:].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        rtype, rest = rhs[:sp], rhs[sp + 1:].lstrip()
    par = rest.find("(")
    if par < 0:
        return None
    opcode = rest[:par].strip()
    if not re.fullmatch(r"[\w\-]+", opcode):
        return None
    return name, rtype, opcode, rest[par + 1:]

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# ring wire-bytes per device as a multiple of the OPERAND bytes:
#   all-gather operand = the local shard -> receive (n-1) shards
#   reduce-scatter operand = the full local buffer -> send (n-1) chunks of /n
#   all-reduce operand = full buffer -> RS + AG = 2(n-1)/n
#   all-to-all operand = full local buffer -> (n-1)/n leaves the device
_RING_FACTOR = {"all-reduce": lambda n: 2 * (n - 1) / n,
                "all-gather": lambda n: float(n - 1),
                "reduce-scatter": lambda n: (n - 1) / n,
                "all-to-all": lambda n: (n - 1) / n,
                "collective-permute": lambda n: 1.0}


# XLA-CPU's float-normalization pass rewrites bf16 storage (incl. while-loop
# carries) to f32; on TPU these buffers stay bf16. The analyzer therefore
# counts float buffers at the intended activation/weight policy width
# (float_bytes=2). fp32 optimizer streaming is added analytically by the
# roofline layer — it lives in elementwise fusions outside the strict op set.
_FLOAT_TYPES = {"f16", "bf16", "f32", "f64"}
FLOAT_BYTES = 2


def shape_bytes(type_str: str, float_bytes: int = None) -> int:
    """Total bytes of possibly-tuple HLO type string."""
    fb = FLOAT_BYTES if float_bytes is None else float_bytes
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        b = fb if dtype in _FLOAT_TYPES else _DTYPE_BYTES[dtype]
        total += n * b
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    rest: str          # everything after the opening paren


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symtab: Dict[str, str]  # op name -> result type string


# ops whose HLO metadata op_name contains these scopes are bucketed
# separately: the Pallas runtime kernels keep this traffic in VMEM
SCOPED = ("flash_core",)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    scoped_bytes: float = 0.0     # flash_core traffic (VMEM-resident on TPU)
    coll_payload: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_wire: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_count: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def scaled(self, k: float) -> "Cost":
        c = Cost(self.flops * k, self.hbm_bytes * k, self.scoped_bytes * k)
        for d_src, d_dst in ((self.coll_payload, c.coll_payload),
                             (self.coll_wire, c.coll_wire),
                             (self.coll_count, c.coll_count)):
            for kk, v in d_src.items():
                d_dst[kk] = v * k
        return c

    def add(self, other: "Cost"):
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        self.scoped_bytes += other.scoped_bytes
        for d_src, d_dst in ((other.coll_payload, self.coll_payload),
                             (other.coll_wire, self.coll_wire),
                             (other.coll_count, self.coll_count)):
            for kk, v in d_src.items():
                d_dst[kk] += v

    @property
    def collective_payload_total(self) -> float:
        return sum(self.coll_payload.values())

    @property
    def collective_wire_total(self) -> float:
        return sum(self.coll_wire.values())

    def summary(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "flash_scoped_bytes": self.scoped_bytes,
            "collective_payload_bytes": dict(self.coll_payload),
            "collective_wire_bytes": dict(self.coll_wire),
            "collective_counts": dict(self.coll_count),
            "collective_payload_total": self.collective_payload_total,
            "collective_wire_total": self.collective_wire_total,
        }


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if current is None:
            m = _COMP_RE.match(line)
            if m and stripped.endswith("{"):
                current = Computation(m.group(1), [], {})
            continue
        if stripped == "}" or stripped.startswith("}"):
            comps[current.name] = current
            current = None
            continue
        parsed = _parse_op_line(line)
        if parsed:
            name, rtype, opcode, rest = parsed
            current.ops.append(Op(name, rtype, opcode, rest))
            current.symtab[name] = rtype
    return comps


def _dot_flops(op: Op, comp: Computation) -> float:
    _, rdims = _shape_dims(op.result_type)
    out = 1
    for d in rdims:
        out *= d
    m = re.search(r"lhs_contracting_dims={([\d,]*)}", op.rest)
    # newer XLA prints inline operand types ("dot(f32[16,64] %lhs, ...)"),
    # so take the first %-prefixed operand rather than the first token
    operands = _operand_names(op)
    contract = 1
    if m and operands:
        lt = comp.symtab.get(operands[0], "")
        if not lt:
            tm = re.match(r"\s*(\([^)]*\)|[\w\[\]{},]+)\s+%" +
                          re.escape(operands[0]), op.rest)
            lt = tm.group(1) if tm else ""
        _, ldims = _shape_dims(lt)
        for idx in (int(i) for i in m.group(1).split(",") if i):
            if idx < len(ldims):
                contract *= ldims[idx]
    return 2.0 * out * contract


def _operand_names(op: Op) -> List[str]:
    # operands are leading %names inside the parens, before any ), attrs
    depth = 1
    body = []
    for ch in op.rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        body.append(ch)
    return re.findall(r"%([\w\.\-]+)", "".join(body))


def _group_size(op: Op, n_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", op.rest)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"sizes=\[(\d+),(\d+)\]", op.rest)
    if m:
        return int(m.group(2))
    return n_devices


# "strict" HBM model: ops whose operands/results must stream through HBM even
# under TPU-grade fusion (matmul weight/activation reads, cache read/update,
# dispatch sorts, collective payloads). Elementwise chains / norms / softmax
# are assumed fused into producer epilogues (that is what the Pallas runtime
# kernels do in VMEM), and CPU-backend `copy`/layout noise is excluded —
# see EXPERIMENTS.md §Roofline "HBM-traffic proxy".
_MEM_OPS = {"dot", "convolution", "dynamic-slice", "dynamic-update-slice",
            "gather", "scatter", "sort"} | set(COLLECTIVES)
_CHEAP: set = set()


def analyze(text: str, n_devices: int, entry: Optional[str] = None) -> Cost:
    comps = parse_hlo(text)
    if entry is None:
        cands = [c for c in comps if c.startswith("main")] or list(comps)
        entry = cands[0]
    memo: Dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()  # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        total = Cost()
        for op in comp.ops:
            total.add(op_cost(op, comp))
        memo[name] = total
        return total

    def op_cost(op: Op, comp: Computation) -> Cost:
        c = Cost()
        if op.opcode == "while":
            body = re.search(r"body=%?([\w\.\-]+)", op.rest)
            cond = re.search(r"condition=%?([\w\.\-]+)", op.rest)
            trips = 1
            m = _TRIP_RE.search(op.rest)
            if m:
                trips = int(m.group(1))
            elif cond and cond.group(1) in comps:
                consts = [int(x) for x in re.findall(
                    r"constant\((\d+)\)", "\n".join(
                        o.rest for o in comps[cond.group(1)].ops))]
                trips = max(consts) if consts else 1
            if body:
                c.add(comp_cost(body.group(1)).scaled(trips))
            return c
        if op.opcode in ("call", "custom-call", "conditional", "async-start"):
            for target in re.findall(r"(?:to_apply|calls|called_computation)"
                                     r"=%?([\w\.\-]+)", op.rest):
                c.add(comp_cost(target))
        if op.opcode == "fusion":
            m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
            if m:
                inner = comps.get(m.group(1))
                if inner:
                    fusion_scoped = any(s in op.rest for s in SCOPED)
                    for iop in inner.ops:
                        if iop.opcode == "dot":
                            c.flops += _dot_flops(iop, inner)
                        b = _mem_bytes(iop, inner)
                        c.hbm_bytes += b
                        if b and (fusion_scoped
                                  or any(s in iop.rest for s in SCOPED)):
                            c.scoped_bytes += b
        if op.opcode == "dot":
            c.flops += _dot_flops(op, comp)
        if op.opcode == "convolution":
            _, rdims = _shape_dims(op.result_type)
            out = 1
            for d in rdims:
                out *= d
            c.flops += 2.0 * out  # lower bound; convs are stubs here
        if op.opcode in COLLECTIVES or any(op.opcode.startswith(k + "-start")
                                           for k in COLLECTIVES):
            kind = next(k for k in COLLECTIVES if op.opcode.startswith(k))
            payload = sum(shape_bytes(comp.symtab.get(o, ""))
                          for o in _operand_names(op))
            gs = _group_size(op, n_devices)
            c.coll_payload[kind] += payload
            c.coll_wire[kind] += payload * _RING_FACTOR[kind](max(gs, 1))
            c.coll_count[kind] += 1
        b = _mem_bytes(op, comp)
        c.hbm_bytes += b
        if b and any(s in op.rest for s in SCOPED):
            c.scoped_bytes += b
        return c

    return comp_cost(entry)


def _mem_bytes(op: Op, comp: Computation) -> float:
    """Strict per-op HBM bytes (see _MEM_OPS note)."""
    if op.opcode not in _MEM_OPS:
        return 0.0
    operands = _operand_names(op)
    if op.opcode == "dynamic-update-slice":
        # aliased in-place on TPU: only the update slice moves
        return float(shape_bytes(comp.symtab.get(operands[1], ""))
                     if len(operands) > 1 else 0)
    if op.opcode in ("dynamic-slice", "gather"):
        return float(shape_bytes(op.result_type))       # bytes actually read
    if op.opcode == "scatter":
        return float(shape_bytes(comp.symtab.get(operands[2], ""))
                     if len(operands) > 2 else shape_bytes(op.result_type))
    b = shape_bytes(op.result_type)
    for o in operands:
        b += shape_bytes(comp.symtab.get(o, ""))
    return float(b)


def analyze_compiled(compiled, n_devices: int) -> Dict:
    cost = analyze(compiled.as_text(), n_devices)
    out = cost.summary()
    try:
        xla = compiled.cost_analysis()
        out["xla_flops_single_body"] = float(xla.get("flops", 0.0))
        out["xla_bytes_single_body"] = float(xla.get("bytes accessed", 0.0))
    except Exception:
        pass
    try:
        mem = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        }
    except Exception:
        pass
    return out
