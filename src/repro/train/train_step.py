"""The jit-able training step (and the serve steps the dry-run lowers)."""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, apply_updates


def make_train_step(cfg, ctx, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, batch, cfg, ctx))(params)
        params, opt_state, metrics = apply_updates(params, grads, opt_state,
                                                   opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics
    return train_step


def make_prefill_step(cfg, ctx, max_len=None, cache_dtype=jnp.bfloat16):
    def prefill_step(params, tokens, prefix_embeds=None):
        last, state = T.prefill(params, tokens, cfg, ctx,
                                prefix_embeds=prefix_embeds, max_len=max_len,
                                cache_dtype=cache_dtype)
        next_tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        return next_tok, state
    return prefill_step


def make_decode_step(cfg, ctx):
    def serve_step(params, state, tokens):
        logits, state = T.decode_step(params, state, tokens, cfg, ctx)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, state
    return serve_step
