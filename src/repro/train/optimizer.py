"""ZeRO-sharded AdamW (no optax dependency).

Moment tensors reuse the parameter shardings, so sharding params FSDP-style
(DESIGN.md §5) automatically gives ZeRO-1/3 semantics under GSPMD: each device
stores only its shard of m/v and the update is computed shard-locally after
the gradient reduce-scatter GSPMD inserts.

``state_dtype`` is a §Perf lever: fp32 (default, faithful to standard
practice) or bf16 (halves optimizer HBM — how llama3-405b train_4k fits a
single v5e pod, see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    state_dtype: Any = jnp.float32


def init_opt_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params, cfg: AdamWConfig):
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, abstract_params),
        "v": jax.tree_util.tree_map(zeros, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_state_shardings(param_shardings, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return {
        "m": param_shardings,
        "v": param_shardings,
        "step": NamedSharding(mesh, P()),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
