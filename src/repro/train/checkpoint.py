"""Fault-tolerant checkpointing (pure JAX/numpy, no orbax).

* step-atomic: writes to ``<dir>/tmp-<step>`` then renames to ``step-<step>``
  (a crashed writer never corrupts the restore point)
* elastic: restore maps arrays onto the *current* mesh via the param-spec
  sharding rules, so the device count/layout may differ from the writer's
* async: ``save_async`` snapshots to host (device_get) on the caller thread,
  then serialises on a background thread so the train loop keeps stepping
* retention: keeps the newest ``keep`` checkpoints
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save(tree, directory: str, step: int, keep: int = 3):
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"tmp-{step}"
    final = d / f"step-{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    keys, vals, _ = _flatten(tree)
    host = [np.asarray(jax.device_get(v)) for v in vals]
    np.savez(tmp / "arrays.npz", **{f"a{i}": h for i, h in enumerate(host)})
    (tmp / "manifest.json").write_text(json.dumps({"step": step, "keys": keys}))
    os.replace(tmp, final)                       # atomic commit
    _gc(d, keep)
    return str(final)


def save_async(tree, directory: str, step: int, keep: int = 3
               ) -> threading.Thread:
    """Device->host snapshot happens now; disk write on a worker thread."""
    keys, vals, _ = _flatten(tree)
    host = [np.asarray(jax.device_get(v)) for v in vals]

    def _write():
        d = Path(directory)
        d.mkdir(parents=True, exist_ok=True)
        tmp = d / f"tmp-{step}"
        final = d / f"step-{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez(tmp / "arrays.npz", **{f"a{i}": h for i, h in enumerate(host)})
        (tmp / "manifest.json").write_text(
            json.dumps({"step": step, "keys": keys}))
        os.replace(tmp, final)
        _gc(d, keep)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(directory: str) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("-")[1]) for p in d.glob("step-*"))
    return steps[-1] if steps else None


def restore(like_tree, directory: str, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``like_tree`` (abstract or concrete);
    ``shardings`` (same pytree structure) re-shards onto the current mesh —
    elastic restarts just pass the new mesh's shardings."""
    d = Path(directory)
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    src = d / f"step-{step:09d}"
    data = np.load(src / "arrays.npz")
    keys, vals, treedef = _flatten(like_tree)
    manifest = json.loads((src / "manifest.json").read_text())
    assert manifest["keys"] == keys, "checkpoint/model structure mismatch"
    arrays = [data[f"a{i}"] for i in range(len(keys))]
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrays), step


def _gc(d: Path, keep: int):
    steps = sorted(d.glob("step-*"))
    for old in steps[:-keep]:
        shutil.rmtree(old, ignore_errors=True)
