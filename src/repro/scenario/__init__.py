"""Unified Scenario API: one declarative spec, three fidelities.

    sc = get_scenario("ds32b-8xh200-dp4tp2")
    sc.to_plan()      # ranked analytical PlanEstimates (seconds)
    sc.to_engine()    # one virtual-clock InferenceEngine replica
    sc.to_cluster()   # the full ClusterRuntime fleet

See docs/scenario.md for the spec schema and walkthrough.
"""
from repro.scenario.compile import (Resolved, ResolvedGroup, aggregate_plan,
                                    estimate_fleet, planner_workload,
                                    requests, resolve, to_cluster, to_engine,
                                    to_plan, trace)
from repro.scenario.crosscheck import (CrosscheckReport, bounds_for,
                                       crosscheck)
from repro.scenario.registry import (SCENARIOS, get_scenario,
                                     register_scenario, variant)
from repro.scenario.spec import (AUTOSCALE_POLICIES, HARDWARE, PROCESSES,
                                 ROLES, WORKLOADS, Autoscaler, Diagnostic,
                                 ModelRef, Scenario, SLOClass, Traffic,
                                 WorkerGroup, register_hardware,
                                 register_workload)

__all__ = [
    "Scenario", "ModelRef", "WorkerGroup", "Traffic", "SLOClass",
    "Autoscaler", "AUTOSCALE_POLICIES", "Diagnostic",
    "HARDWARE", "WORKLOADS", "ROLES", "PROCESSES",
    "register_hardware", "register_workload",
    "Resolved", "ResolvedGroup", "resolve", "aggregate_plan",
    "estimate_fleet", "planner_workload", "trace", "requests",
    "to_plan", "to_engine", "to_cluster",
    "crosscheck", "CrosscheckReport", "bounds_for",
    "SCENARIOS", "get_scenario", "register_scenario", "variant",
]
