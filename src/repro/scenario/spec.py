"""Declarative serving scenarios — the paper's decision framework as an API.

A ``Scenario`` is one frozen, dict/JSON-round-trippable answer to "what am I
deploying, on what, under which traffic, against which SLOs?". The same spec
compiles to three fidelities (``repro.scenario.compile``):

  * ``to_plan()``    — ranked analytical ``PlanEstimate``s (seconds to run)
  * ``to_engine()``  — one virtual-clock ``InferenceEngine`` replica (minutes)
  * ``to_cluster()`` — a full ``ClusterRuntime`` fleet with routing, arrival
                       replay and migration (the serving-level ground truth)

so a what-if question ("Qwen-32B on 8xH200 at 12 req/s with interactive
SLOs — DP4xTP2 or disagg?") is asked once and answered at increasing cost.
Per-``WorkerGroup`` hardware makes heterogeneous fleets expressible (ROADMAP);
the ``slos`` tuple is the hook for multi-tenant SLO classes.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

from repro.cluster.worker import ROLES
from repro.core import perf_model as pm
from repro.core.metrics import SLO
from repro.data.reasoning import CHAT, LONG_REASONING, REASONING, WorkloadSpec

# --------------------------------------------------------------- name tables
# Mutable registries so downstream code can add hardware / workload profiles
# without touching the spec schema; specs stay JSON-serialisable names.
HARDWARE: Dict[str, pm.Hardware] = {"h200": pm.H200, "v5e": pm.V5E}
WORKLOADS: Dict[str, WorkloadSpec] = {
    "reasoning": REASONING,
    "chat": CHAT,
    "long_reasoning": LONG_REASONING,
}

PROCESSES = ("closed", "poisson", "gamma", "trace", "piecewise")

AUTOSCALE_POLICIES = ("target_utilization", "slo_guard")


def register_hardware(name: str, hw: pm.Hardware):
    HARDWARE[name] = hw


def register_workload(name: str, spec: WorkloadSpec):
    WORKLOADS[name] = spec


def _lookup(table: Dict[str, Any], name: str, kind: str):
    try:
        return table[name]
    except KeyError:
        raise KeyError(
            f"unknown {kind} {name!r} (have {sorted(table)})") from None


# -------------------------------------------------------------------- pieces
@dataclasses.dataclass(frozen=True)
class ModelRef:
    """A model by registry name plus its numeric formats."""
    name: str
    dtype_bytes: int = 2          # weight/activation width (fp8: 1)
    cache_dtype_bytes: int = 2    # KV-cache width (fp8/int8 cache: 1)

    def resolve(self):
        from repro.configs.registry import get_config
        return get_config(self.name)


@dataclasses.dataclass(frozen=True)
class WorkerGroup:
    """``count`` identical workers sharing one role, hardware and plan.

    ``n_pages=None`` means paper-calibrated capacity: every KV token that
    fits after weights + runtime overhead (``pm.kv_capacity_tokens``).
    ``admission=None`` means the role default (prefill workers admit naively
    — their requests never grow KV; everyone else KV-aware, Obs 1/8).
    """
    role: str = "colocated"
    count: int = 1
    hardware: str = "h200"
    plan: pm.ParallelismPlan = pm.ParallelismPlan()
    n_pages: Optional[int] = None
    page_size: int = 16
    max_seqs: int = 256
    max_batched_tokens: int = 8192
    chunk_size: int = 512
    admission: Optional[str] = None
    autotune: bool = False
    prefix: str = ""              # worker-name prefix (defaults to role)

    def __post_init__(self):
        if self.role not in ROLES:
            raise ValueError(f"unknown role {self.role!r} (have {ROLES})")
        if self.count < 1:
            raise ValueError(f"group needs count >= 1, got {self.count}")
        if not isinstance(self.plan, pm.ParallelismPlan):
            object.__setattr__(self, "plan", pm.ParallelismPlan(**self.plan))

    @property
    def devices(self) -> int:
        return self.count * self.plan.devices


@dataclasses.dataclass(frozen=True)
class Traffic:
    """Arrival process x (ISL, OSL) distribution (paper §III-B).

    ``closed`` submits everything at t=0 (the pre-cluster benchmark mode);
    ``poisson``/``gamma`` are open-loop; ``trace`` replays explicit arrival
    times; ``piecewise`` is a nonhomogeneous Poisson process with a
    piecewise-constant rate (``phases`` = (duration_s, rate) segments — the
    diurnal/bursty traffic autoscaling exists for). The same ``seed`` always
    draws the same request lengths, so fleets compared under different
    processes see identical work.

    ``class_mix`` is the multi-tenant traffic split: (SLO-class name, weight)
    pairs; each request in the compiled trace is deterministically tagged
    with a class drawn from this mix (same seed -> same tagging, so
    class-aware and class-blind fleets see identical per-request tiers).
    Empty = single-tenant (every request gets the scenario's default class).
    """
    process: str = "closed"
    rate: float = 0.0             # req/s (poisson | gamma)
    cv: float = 2.0               # gamma burstiness (cv=1 is Poisson)
    arrivals: Tuple[float, ...] = ()   # explicit times (trace)
    phases: Tuple[Tuple[float, float], ...] = ()  # (duration_s, rate) segs
    workload: str = "reasoning"
    n_requests: int = 150
    osl_cap: Optional[int] = None
    seed: int = 0
    class_mix: Tuple[Tuple[str, float], ...] = ()

    def __post_init__(self):
        if self.process not in PROCESSES:
            raise ValueError(
                f"unknown arrival process {self.process!r} (have {PROCESSES})")
        if self.process in ("poisson", "gamma") and self.rate <= 0:
            raise ValueError(f"{self.process} traffic needs rate > 0")
        object.__setattr__(self, "arrivals", tuple(self.arrivals))
        if self.process == "trace" and len(self.arrivals) < self.n_requests:
            raise ValueError(f"trace has {len(self.arrivals)} arrivals, "
                             f"need {self.n_requests}")
        phases = tuple((float(d), float(r)) for d, r in self.phases)
        object.__setattr__(self, "phases", phases)
        if self.process == "piecewise":
            if not phases:
                raise ValueError("piecewise traffic needs at least one "
                                 "(duration_s, rate) phase")
            if any(d <= 0 for d, _ in phases) or any(r < 0 for _, r in phases):
                raise ValueError(f"piecewise phases need duration > 0 and "
                                 f"rate >= 0: {phases}")
            if all(r == 0 for _, r in phases):
                raise ValueError("piecewise traffic needs at least one "
                                 "phase with rate > 0")
        mix = tuple((str(n), float(w)) for n, w in self.class_mix)
        if any(w <= 0 for _, w in mix):
            raise ValueError(f"class_mix weights must be positive: {mix}")
        if len({n for n, _ in mix}) != len(mix):
            raise ValueError(f"class_mix names must be unique: {mix}")
        object.__setattr__(self, "class_mix", mix)

    def workload_spec(self) -> WorkloadSpec:
        return _lookup(WORKLOADS, self.workload, "workload")


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A named latency contract (the multi-tenant hook: interactive vs
    batch). ``None`` targets are unconstrained. ``priority`` is the class's
    scheduling urgency (higher = more latency-critical): urgent classes jump
    waiting queues, draw on the reserved KV headroom slice, and are preferred
    by class-aware routing; preemption victims come from the least urgent
    class first."""
    name: str = "interactive"
    ttft_s: Optional[float] = None
    tpot_s: Optional[float] = None
    priority: int = 0

    def slo(self) -> SLO:
        return SLO(ttft_s=self.ttft_s, tpot_s=self.tpot_s)


@dataclasses.dataclass(frozen=True)
class Autoscaler:
    """Elastic sizing for one fleet role (``repro.cluster.autoscale``).

    The named ``role``'s WorkerGroup ``count`` becomes the *initial* pool
    size; the controller then holds the provisioned count (active + warming)
    inside [``min_workers``, ``max_workers``], deciding every ``tick_s``
    seconds of fleet time with ``cooldown_s`` between actions. New replicas
    pay the modeled weight-load cold start plus ``cold_start_extra_s``
    (checkpoint fetch / container spin-up) before serving.

    Policy knobs: ``target_utilization`` tracks ``target_kv_util`` inside a
    ``band`` hysteresis; ``slo_guard`` scales up when attainment drops below
    ``attain_floor`` (or KV utilization passes ``util_ceiling``) and down
    only below ``scale_down_util``."""
    policy: str = "target_utilization"
    role: str = "colocated"
    min_workers: int = 1
    max_workers: int = 8
    tick_s: float = 2.0
    cooldown_s: float = 10.0
    target_kv_util: float = 0.60
    band: float = 0.15
    attain_floor: float = 0.90
    util_ceiling: float = 0.85
    scale_down_util: float = 0.35
    surge_ratio: float = 1.5      # fast/slow arrival-rate ratio that counts
                                  # as a load surge (slo_guard feedforward)
    # opt-in slo_guard trigger: scale up when the EWMA fraction of the pool
    # classified Capacity-Bound by the repro.obs regime rules (preemption
    # evidence, or saturated KV while queued) exceeds this; None disables
    capacity_frac_ceiling: Optional[float] = None
    ewma_alpha: float = 0.4
    cold_start_extra_s: float = 0.0

    def __post_init__(self):
        if self.policy not in AUTOSCALE_POLICIES:
            raise ValueError(f"unknown autoscale policy {self.policy!r} "
                             f"(have {AUTOSCALE_POLICIES})")
        if self.role not in ROLES:
            raise ValueError(f"unknown role {self.role!r} (have {ROLES})")
        if self.min_workers < 1 or self.max_workers < self.min_workers:
            raise ValueError(f"need 1 <= min_workers <= max_workers, got "
                             f"[{self.min_workers}, {self.max_workers}]")
        if self.tick_s <= 0:
            raise ValueError(f"tick_s must be > 0, got {self.tick_s}")
        if self.cooldown_s < 0:
            raise ValueError(f"cooldown_s must be >= 0, got "
                             f"{self.cooldown_s}")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha must be in (0, 1], got "
                             f"{self.ewma_alpha}")
        if self.cold_start_extra_s < 0:
            raise ValueError(f"cold_start_extra_s must be >= 0, got "
                             f"{self.cold_start_extra_s}")
        if self.capacity_frac_ceiling is not None \
                and not 0.0 < self.capacity_frac_ceiling <= 1.0:
            raise ValueError(f"capacity_frac_ceiling must be in (0, 1], got "
                             f"{self.capacity_frac_ceiling}")


REBALANCE_POLICIES = ("kv_pressure",)


@dataclasses.dataclass(frozen=True)
class Rebalance:
    """Decode→decode rebalancing (``repro.cluster.rebalance``): when a
    decode worker's KV utilization crosses ``kv_high`` while a peer could
    adopt one of its running requests and keep ``dst_headroom`` of its own
    pool free, migrate that victim over the eject/KV-transfer/inject path
    *before* the source's preemption storm (paper Obs 4 mitigation).
    ``cooldown_s`` rate-limits decisions, ``max_inflight`` bounds concurrent
    rebalance transfers, and ``check_every_s`` is how often the event loop
    consults the policy on a fresh ``FleetView``."""
    policy: str = "kv_pressure"
    kv_high: float = 0.90         # source trigger (RegimeRules.kv_saturated)
    dst_headroom: float = 0.10    # post-adoption pool fraction the
                                  # destination must keep free
    min_remaining: int = 64       # don't ship nearly-finished decodes
    cooldown_s: float = 0.25
    max_inflight: int = 1
    check_every_s: float = 0.05

    def __post_init__(self):
        if self.policy not in REBALANCE_POLICIES:
            raise ValueError(f"unknown rebalance policy {self.policy!r} "
                             f"(have {REBALANCE_POLICIES})")
        if not 0.0 < self.kv_high <= 1.0:
            raise ValueError(f"kv_high must be in (0, 1], got {self.kv_high}")
        if not 0.0 <= self.dst_headroom < 1.0:
            raise ValueError(f"dst_headroom must be in [0, 1), got "
                             f"{self.dst_headroom}")
        if self.min_remaining < 1:
            raise ValueError(f"min_remaining must be >= 1, got "
                             f"{self.min_remaining}")
        if self.cooldown_s < 0:
            raise ValueError(f"cooldown_s must be >= 0, got "
                             f"{self.cooldown_s}")
        if self.max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got "
                             f"{self.max_inflight}")
        if self.check_every_s <= 0:
            raise ValueError(f"check_every_s must be > 0, got "
                             f"{self.check_every_s}")


# --------------------------------------------------------------- diagnostics
@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One feasibility finding from ``Scenario.check()``: ``code`` is a
    stable machine-readable kind, ``field`` the spec path it points at."""
    code: str
    severity: str                 # "error" | "warning"
    field: str
    message: str

    def format(self) -> str:
        return f"[{self.code}] {self.severity} at {self.field}: {self.message}"


# ------------------------------------------------------------------ scenario
@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    model: ModelRef
    fleet: Tuple[WorkerGroup, ...]
    traffic: Traffic = Traffic()
    slos: Tuple[SLOClass, ...] = ()
    routing: str = "memory_aware"        # RoutingPolicy name
    dispatch: str = "least_headroom"     # DispatchPolicy name
    transfer_dtype_bytes: int = 2        # KV wire format for migration
    class_kv_headroom: float = 0.0       # pool fraction only the top-urgency
                                         # SLO class may use (tier slice)
    autoscaler: Optional["Autoscaler"] = None  # elastic sizing (one role)
    rebalance: Optional["Rebalance"] = None    # decode→decode rebalancing
    notes: str = ""

    def __post_init__(self):
        if isinstance(self.model, dict):
            object.__setattr__(self, "model", ModelRef(**self.model))
        fleet = tuple(g if isinstance(g, WorkerGroup) else WorkerGroup(**g)
                      for g in self.fleet)
        slos = tuple(s if isinstance(s, SLOClass) else SLOClass(**s)
                     for s in self.slos)
        object.__setattr__(self, "fleet", fleet)
        object.__setattr__(self, "slos", slos)
        if not self.fleet:
            raise ValueError("scenario needs at least one worker group")
        roles = {g.role for g in self.fleet}
        if "prefill" in roles and "decode" not in roles:
            raise ValueError("prefill groups need a decode group to "
                             "migrate into")
        if not 0.0 <= self.class_kv_headroom < 1.0:
            raise ValueError(f"class_kv_headroom must be in [0, 1), got "
                             f"{self.class_kv_headroom}")
        known = {c.name for c in self.slos}
        unknown = [n for n, _ in self.traffic.class_mix if n not in known]
        if unknown:
            raise ValueError(
                f"traffic class_mix names {unknown} have no SLOClass in "
                f"scenario {self.name!r} (have {sorted(known)})")
        if isinstance(self.autoscaler, dict):
            object.__setattr__(self, "autoscaler",
                               Autoscaler(**self.autoscaler))
        if isinstance(self.rebalance, dict):
            object.__setattr__(self, "rebalance",
                               Rebalance(**self.rebalance))
        if self.autoscaler is not None:
            a = self.autoscaler
            grp = [g for g in self.fleet if g.role == a.role]
            if not grp:
                raise ValueError(
                    f"autoscaler targets role {a.role!r} but the fleet has "
                    f"no such group (roles: {sorted(roles)})")
            if len(grp) > 1:
                raise ValueError(
                    f"autoscaler targets role {a.role!r} but {len(grp)} "
                    f"groups share it — minted replicas would be ambiguous; "
                    f"use a single group for the scaled role")
            n0 = grp[0].count
            if not a.min_workers <= n0 <= a.max_workers:
                raise ValueError(
                    f"initial {a.role} count {n0} outside autoscaler bounds "
                    f"[{a.min_workers}, {a.max_workers}]")

    # ------------------------------------------------------------ properties
    @property
    def n_devices(self) -> int:
        return sum(g.devices for g in self.fleet)

    @property
    def disaggregated(self) -> bool:
        return any(g.role == "prefill" for g in self.fleet)

    def slo(self, name: Optional[str] = None) -> Optional[SLO]:
        """The named SLO class (default: the first one) as a core SLO."""
        if not self.slos:
            return None
        if name is None:
            return self.slos[0].slo()
        for c in self.slos:
            if c.name == name:
                return c.slo()
        raise KeyError(f"no SLO class {name!r} in scenario {self.name!r} "
                       f"(have {[c.name for c in self.slos]})")

    def slo_map(self) -> Dict[str, SLO]:
        """Every SLO class as name -> core SLO (the class-conditional
        metrics table; ``slos[0]`` is the default class)."""
        return {c.name: c.slo() for c in self.slos}

    def class_priorities(self) -> Dict[str, int]:
        """Class name -> scheduling urgency, for admission/scheduler/routing
        (empty or uniform = class-blind behaviour)."""
        return {c.name: c.priority for c in self.slos}

    # ------------------------------------------------- dict/JSON round trip
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Scenario":
        d = dict(d)
        d["model"] = ModelRef(**d["model"])
        d["fleet"] = tuple(WorkerGroup(**g) for g in d["fleet"])
        d["traffic"] = Traffic(**d.get("traffic", {}))
        d["slos"] = tuple(SLOClass(**s) for s in d.get("slos", ()))
        if d.get("autoscaler") is not None:
            d["autoscaler"] = Autoscaler(**d["autoscaler"])
        if d.get("rebalance") is not None:
            d["rebalance"] = Rebalance(**d["rebalance"])
        return cls(**d)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "Scenario":
        return cls.from_dict(json.loads(s))

    # ------------------------------------------------------------ feasibility
    def check(self, include_warnings: bool = False) -> list:
        """Static feasibility diagnostics — no engine or cluster is built,
        no trace is drawn. Catches the spec mistakes the constructor can't
        see (they need the *resolved* model/hardware/workload): a KV pool
        too small for the workload's structural max request, TP degrees
        that don't divide the head counts, PP deeper than the layer stack,
        a ``class_mix`` that doesn't sum to 1, autoscaler bounds that
        contradict the fleet, degenerate piecewise phases.

        Returns ``Diagnostic`` rows, errors only by default
        (``include_warnings=True`` adds advisory findings such as a PP
        degree that divides the layers unevenly). An empty list means the
        spec compiles and every structurally possible request fits."""
        diags: list = []

        def add(code, severity, field, message):
            diags.append(Diagnostic(code=code, severity=severity,
                                    field=field, message=message))

        cfg = None
        try:
            cfg = self.model.resolve()
        except KeyError as e:
            add("unknown_model", "error", "model.name", str(e))
        workload = None
        try:
            workload = self.traffic.workload_spec()
        except KeyError as e:
            add("unknown_workload", "error", "traffic.workload", str(e))

        self._check_fleet_capacity(cfg, workload, add)
        self._check_parallelism(cfg, add)
        self._check_traffic(add)
        self._check_autoscaler(add)
        self._check_rebalance(add)
        if include_warnings:
            return diags
        return [d for d in diags if d.severity == "error"]

    def _check_fleet_capacity(self, cfg, workload, add):
        """Per-group KV pool vs the workload's structural max request."""
        if workload is None:
            return
        osl_eff = min(workload.osl_max,
                      self.traffic.osl_cap or workload.osl_max)
        for i, g in enumerate(self.fleet):
            field = f"fleet[{i}]"
            try:
                hw = _lookup(HARDWARE, g.hardware, "hardware")
            except KeyError as e:
                add("unknown_hardware", "error", f"{field}.hardware", str(e))
                continue
            n_pages = g.n_pages
            if n_pages is None:
                if cfg is None:
                    continue          # capacity default needs the model
                from repro.cluster.worker import default_n_pages
                n_pages = default_n_pages(cfg, g.plan, hw,
                                          self.model.dtype_bytes, g.page_size,
                                          self.model.cache_dtype_bytes)
            cap = n_pages * g.page_size
            # a prefill worker holds prompt + first token only; everyone
            # else must hold the full context at last decode
            need = workload.isl_max + 2 if g.role == "prefill" \
                else workload.isl_max + osl_eff + 1
            if cap < need:
                add("kv_pool_too_small", "error", f"{field}.n_pages",
                    f"{g.role} KV pool holds {cap} tokens but the "
                    f"{self.traffic.workload!r} workload's largest request "
                    f"needs {need} (isl_max {workload.isl_max}"
                    + ("" if g.role == "prefill"
                       else f" + capped osl {osl_eff}") + " + 1)")
            if g.max_batched_tokens < g.chunk_size:
                add("chunk_over_budget", "warning", f"{field}.chunk_size",
                    f"chunk_size {g.chunk_size} exceeds max_batched_tokens "
                    f"{g.max_batched_tokens}; prefill chunks will be "
                    f"truncated to the budget")

    def _check_parallelism(self, cfg, add):
        if cfg is None:
            return
        for i, g in enumerate(self.fleet):
            field = f"fleet[{i}].plan"
            p = g.plan
            if p.tp > 1:
                if cfg.n_heads % p.tp:
                    add("tp_heads", "error", field,
                        f"tp={p.tp} does not divide n_heads={cfg.n_heads}")
                if cfg.attention != "mla" and cfg.n_kv_heads % p.tp:
                    add("tp_kv_heads", "error", field,
                        f"tp={p.tp} does not divide "
                        f"n_kv_heads={cfg.n_kv_heads} (KV-head shards "
                        f"would be uneven)")
            if p.pp > 1:
                if p.pp > cfg.n_layers:
                    add("pp_layers", "error", field,
                        f"pp={p.pp} exceeds n_layers={cfg.n_layers} "
                        f"(empty pipeline stages)")
                elif cfg.n_layers % p.pp:
                    add("pp_imbalance", "warning", field,
                        f"pp={p.pp} does not divide "
                        f"n_layers={cfg.n_layers}; the deepest stage "
                        f"bounds every microbatch")
            if p.ep > 1 and cfg.moe is not None and cfg.moe.n_experts \
                    and cfg.moe.n_experts % p.ep:
                add("ep_imbalance", "warning", field,
                    f"ep={p.ep} does not divide "
                    f"n_experts={cfg.moe.n_experts}; expert shards would "
                    f"be uneven")

    def _check_traffic(self, add):
        t = self.traffic
        if t.class_mix:
            total = sum(w for _, w in t.class_mix)
            if abs(total - 1.0) > 1e-6:
                add("class_mix_sum", "error", "traffic.class_mix",
                    f"class_mix weights sum to {total}, not 1")
        if t.process == "piecewise":
            # re-validated without raising: a spec corrupted after
            # construction (or built through a future non-validating path)
            # still gets a diagnostic instead of a mid-run surprise
            if not t.phases:
                add("phases_empty", "error", "traffic.phases",
                    "piecewise traffic has no (duration_s, rate) phases")
            elif any(d <= 0 for d, _ in t.phases):
                add("phases_nonmonotone", "error", "traffic.phases",
                    f"piecewise phase durations must be > 0 (the phase "
                    f"clock must advance): {t.phases}")
            elif all(r == 0 for _, r in t.phases):
                add("phases_silent", "error", "traffic.phases",
                    "every piecewise phase has rate 0: no request ever "
                    "arrives")
        if t.process == "trace" and t.arrivals:
            if any(b < a for a, b in zip(t.arrivals, t.arrivals[1:])):
                add("trace_unsorted", "warning", "traffic.arrivals",
                    "trace arrival times are not sorted; the runtime "
                    "replays them in time order, which reorders rids "
                    "relative to the trace")

    def _check_autoscaler(self, add):
        a = self.autoscaler
        if a is None:
            return
        grp = [(i, g) for i, g in enumerate(self.fleet) if g.role == a.role]
        if not grp:
            add("autoscaler_role", "error", "autoscaler.role",
                f"autoscaler targets role {a.role!r} but the fleet has no "
                f"such group")
            return
        if len(grp) > 1:
            add("autoscaler_role", "error", "autoscaler.role",
                f"{len(grp)} groups share the scaled role {a.role!r}; "
                f"minted replicas would be ambiguous")
        i, g = grp[0]
        if a.min_workers < 1 or a.max_workers < a.min_workers:
            add("autoscaler_bounds", "error", "autoscaler.min_workers",
                f"need 1 <= min_workers <= max_workers, got "
                f"[{a.min_workers}, {a.max_workers}]")
        elif not a.min_workers <= g.count <= a.max_workers:
            add("autoscaler_bounds", "error", f"fleet[{i}].count",
                f"initial {a.role} count {g.count} outside autoscaler "
                f"bounds [{a.min_workers}, {a.max_workers}]")
        if a.min_workers == a.max_workers:
            add("autoscaler_pinned", "warning", "autoscaler.max_workers",
                f"min_workers == max_workers == {a.min_workers}: the "
                f"controller can never act")

    def _check_rebalance(self, add):
        if self.rebalance is None:
            return
        # the rebalancer moves load between decode peers (or colocated peers
        # when there is no decode pool): a singleton adopter pool can never
        # host a migration, so the hook would tick forever for nothing
        role = "decode" if self.disaggregated else "colocated"
        n = sum(g.count for g in self.fleet if g.role == role)
        if n < 2:
            add("rebalance_singleton_pool", "warning", "rebalance.policy",
                f"rebalancing needs >= 2 {role} workers to migrate between; "
                f"the fleet has {n} — the policy can never act")

    # ------------------------------------------------------------ compilers
    # Thin delegates so a spec in hand is one call away from any fidelity
    # (the real work — one shared resolution pass — lives in
    # repro.scenario.compile).
    def resolve(self):
        from repro.scenario.compile import resolve
        return resolve(self)

    def to_plan(self, n_devices: Optional[int] = None):
        from repro.scenario.compile import to_plan
        return to_plan(self, n_devices=n_devices)

    def to_engine(self, group: int = 0, sanitize: bool = False):
        from repro.scenario.compile import to_engine
        return to_engine(self, group=group, sanitize=sanitize)

    def to_cluster(self, sanitize: bool = False):
        from repro.scenario.compile import to_cluster
        return to_cluster(self, sanitize=sanitize)

    def trace(self):
        from repro.scenario.compile import trace
        return trace(self)

    def crosscheck(self, n_requests: int = 40):
        """Dynamic cross-fidelity consistency: run plan/engine/cluster on a
        small closed-loop shrink of this spec and flag goodput/latency
        ratios outside per-scenario bounds as lint-style ``Finding`` rows
        (``repro.scenario.crosscheck``)."""
        from repro.scenario.crosscheck import crosscheck
        return crosscheck(self, n_requests=n_requests)
