"""Compile a ``Scenario`` to its three fidelities.

All three compilers run the same resolution pass (``resolve``): model name ->
``ModelConfig``, hardware/workload names -> objects, and per-group engine
capacity defaults (``n_pages`` from ``pm.kv_capacity_tokens`` when unset,
role-default admission). That single pass is what keeps the fidelities
consistent — the planner's per-replica KV capacity is the engine's page pool
is the cluster workers' page pool, so disagreements between fidelities are
model error, never plumbing drift.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import List, Optional, Tuple

from repro.core import perf_model as pm
from repro.core import planner
from repro.core.engine import InferenceEngine
from repro.cluster.worker import (Worker, default_admission, default_n_pages,
                                  make_sim_worker)
from repro.data.reasoning import WorkloadSpec
from repro.scenario.spec import HARDWARE, Scenario, WorkerGroup, _lookup


# ------------------------------------------------------------------ resolve
@dataclasses.dataclass(frozen=True)
class ResolvedGroup:
    group: WorkerGroup
    hardware: pm.Hardware
    n_pages: int                  # concrete page pool per worker
    admission: str                # concrete admission mode
    kv_capacity_tokens: int       # n_pages * page_size

    @property
    def plan(self) -> pm.ParallelismPlan:
        return self.group.plan


@dataclasses.dataclass(frozen=True)
class Resolved:
    scenario: Scenario
    model: object                 # ModelConfig
    workload: WorkloadSpec
    groups: Tuple[ResolvedGroup, ...]


def resolve(sc: Scenario) -> Resolved:
    cfg = sc.model.resolve()
    workload = sc.traffic.workload_spec()
    groups = []
    for g in sc.fleet:
        hw = _lookup(HARDWARE, g.hardware, "hardware")
        n_pages = g.n_pages
        if n_pages is None:
            n_pages = default_n_pages(cfg, g.plan, hw, sc.model.dtype_bytes,
                                      g.page_size, sc.model.cache_dtype_bytes)
        admission = g.admission if g.admission is not None \
            else default_admission(g.role)
        groups.append(ResolvedGroup(
            group=g, hardware=hw, n_pages=n_pages, admission=admission,
            kv_capacity_tokens=n_pages * g.page_size))
    return Resolved(scenario=sc, model=cfg, workload=workload,
                    groups=tuple(groups))


def aggregate_plan(sc: Scenario) -> pm.ParallelismPlan:
    """The fleet as one planner-space plan (homogeneous colocated fleets:
    ``count`` replicas fold into the DP degree)."""
    if len(sc.fleet) != 1:
        raise ValueError(
            f"scenario {sc.name!r} has {len(sc.fleet)} worker groups; an "
            "aggregate plan is only defined for a single colocated group")
    g = sc.fleet[0]
    return dataclasses.replace(g.plan, dp=g.count * g.plan.dp)


# -------------------------------------------------------------------- trace
def _process(sc: Scenario):
    from repro.cluster.arrivals import (GammaProcess, PiecewiseRateProcess,
                                        PoissonProcess, TraceProcess)
    t = sc.traffic
    if t.process == "closed":
        return TraceProcess((0.0,) * t.n_requests)
    if t.process == "poisson":
        return PoissonProcess(rate=t.rate)
    if t.process == "gamma":
        return GammaProcess(rate=t.rate, cv=t.cv)
    if t.process == "piecewise":
        return PiecewiseRateProcess(phases=t.phases)
    return TraceProcess(t.arrivals)


def trace(sc: Scenario):
    """The scenario's workload as replayable ``TraceEntry`` rows. Lengths
    depend only on (workload, n_requests, osl_cap, seed) — never on the
    arrival process — so fidelities and fleet variants see identical work.
    Entries are tagged with SLO classes from ``traffic.class_mix``
    (deterministic in the seed; class priorities never change the tagging,
    so class-aware and class-blind variants see the same tiered trace); a
    single-class scenario tags everything with its default class."""
    from repro.cluster.arrivals import assign_classes, make_trace
    t = sc.traffic
    entries = make_trace(_process(sc), sc.traffic.workload_spec(),
                         t.n_requests, seed=t.seed, osl_cap=t.osl_cap)
    if t.class_mix:
        return assign_classes(entries, t.class_mix, seed=t.seed + 2)
    if sc.slos:
        default = sc.slos[0].name
        return [dataclasses.replace(e, slo_class=default) for e in entries]
    return entries


def requests(sc: Scenario) -> List[Tuple[int, int]]:
    """Closed-loop view of the trace: just the (isl, osl) pairs."""
    return [(e.isl, e.osl) for e in trace(sc)]


# ----------------------------------------------------------- fidelity 1: plan
def _reference_group(r: Resolved) -> ResolvedGroup:
    """The group whose replicas hold steady-state decode concurrency — what
    the planner's Workload/capacity statistics must describe. Prefill-only
    groups never grow KV, so the first decode-capable group wins (a
    disaggregated spec's prefill group would otherwise silently cap every
    candidate plan's concurrency)."""
    return next((rg for rg in r.groups if rg.group.role != "prefill"),
                r.groups[0])


def _kv_cap_override(rg: ResolvedGroup) -> Optional[int]:
    return rg.kv_capacity_tokens if rg.group.n_pages is not None else None


def planner_workload(sc: Scenario) -> planner.Workload:
    """The traffic spec reduced to the planner's sufficient statistics,
    measured on the scenario's *actual* trace (same seed, same caps)."""
    entries = trace(sc)
    return planner.Workload(
        n_requests=sc.traffic.n_requests,
        mean_isl=statistics.fmean(e.isl for e in entries),
        mean_osl=statistics.fmean(e.osl for e in entries),
        max_num_seqs=_reference_group(resolve(sc)).group.max_seqs)


def to_plan(sc: Scenario, n_devices: Optional[int] = None
            ) -> List[planner.PlanEstimate]:
    """Rank parallelism plans for the scenario's device budget (analytical
    fidelity). Hardware comes from the reference (decode-capable) group. An
    explicit ``n_pages`` on that group pins per-replica KV capacity for every
    candidate plan — the planner then ranks plans under the same page pool
    the engine/cluster fidelities actually allocate."""
    r = resolve(sc)
    g = _reference_group(r)
    return planner.plan(r.model, g.hardware, n_devices or sc.n_devices,
                        planner_workload(sc), sc.model.dtype_bytes,
                        cache_dtype_bytes=sc.model.cache_dtype_bytes,
                        kv_cap_tokens=_kv_cap_override(g))


def estimate_fleet(sc: Scenario) -> planner.PlanEstimate:
    """Planner estimate of the scenario's own (single-group) fleet, evaluated
    directly — exact even when the fleet's plan is outside
    ``candidate_plans``' ep=tp sweep (e.g. a custom ep)."""
    r = resolve(sc)
    g = r.groups[0]
    return planner.estimate(r.model, aggregate_plan(sc), g.hardware,
                            planner_workload(sc), sc.model.dtype_bytes,
                            cache_dtype_bytes=sc.model.cache_dtype_bytes,
                            kv_cap_tokens=_kv_cap_override(g))


# --------------------------------------------------------- fidelity 2: engine
def _build_worker(r: Resolved, rg: ResolvedGroup, name: str = "",
                  sanitize: bool = False) -> Worker:
    g = rg.group
    sc = r.scenario
    return make_sim_worker(
        r.model, g.plan, rg.hardware, role=g.role, name=name,
        n_pages=rg.n_pages, page_size=g.page_size, max_seqs=g.max_seqs,
        max_batched_tokens=g.max_batched_tokens, chunk_size=g.chunk_size,
        admission=rg.admission, autotune=g.autotune,
        dtype_bytes=sc.model.dtype_bytes,
        cache_dtype_bytes=sc.model.cache_dtype_bytes,
        class_priorities=sc.class_priorities(),
        class_kv_headroom=sc.class_kv_headroom,
        sanitize=sanitize)


def to_engine(sc: Scenario, group: int = 0,
              sanitize: bool = False) -> InferenceEngine:
    """One representative virtual-clock replica of ``fleet[group]`` (engine
    fidelity: real scheduler/allocator dynamics, no fleet effects).
    ``sanitize=True`` turns on per-step invariant checks
    (repro.lint.sanitizer) — read-only, metrics stay bit-identical."""
    r = resolve(sc)
    return _build_worker(r, r.groups[group], sanitize=sanitize).engine


# -------------------------------------------------------- fidelity 3: cluster
def to_cluster(sc: Scenario, sanitize: bool = False):
    """The full fleet: every worker of every group, wired to the scenario's
    routing/dispatch policies and KV-transfer wire format. A spec with an
    ``autoscaler`` gets an ``AutoscaleController`` whose worker factory mints
    replicas from the scaled role's (resolved) group — same capacity, same
    admission, fresh monotonic names continuing the group's numbering.
    ``sanitize=True`` checks fleet + engine invariants every loop iteration
    (repro.lint.sanitizer, covers autoscale-minted workers too) — read-only,
    metrics stay bit-identical."""
    from repro.cluster.autoscale import make_autoscaler
    from repro.cluster.runtime import ClusterConfig, ClusterRuntime
    r = resolve(sc)
    workers = []
    for rg in r.groups:
        prefix = rg.group.prefix or rg.group.role
        for i in range(rg.group.count):
            workers.append(_build_worker(r, rg, name=f"{prefix}{i}"))
    rebalance = None
    rebalance_every = ClusterConfig.rebalance_every_s
    if sc.rebalance is not None:
        from repro.cluster.rebalance import make_rebalancer
        rb = sc.rebalance
        rebalance = make_rebalancer(
            rb.policy, kv_high=rb.kv_high, dst_headroom=rb.dst_headroom,
            min_remaining=rb.min_remaining, cooldown_s=rb.cooldown_s,
            max_inflight=rb.max_inflight)
        rebalance_every = rb.check_every_s
    ccfg = ClusterConfig(policy=sc.routing, dispatcher=sc.dispatch,
                         transfer_dtype_bytes=sc.transfer_dtype_bytes,
                         class_priorities=sc.class_priorities(),
                         name=sc.name, rebalance=rebalance,
                         rebalance_every_s=rebalance_every)
    autoscaler = None
    if sc.autoscaler is not None:
        a = sc.autoscaler
        rg = next(g for g in r.groups if g.group.role == a.role)
        prefix = rg.group.prefix or rg.group.role
        seq = iter(range(rg.group.count, 10 ** 9))

        def factory(r=r, rg=rg, prefix=prefix, seq=seq):
            return _build_worker(r, rg, name=f"{prefix}{next(seq)}")

        autoscaler = make_autoscaler(a, factory, slo=sc.slo())
    return ClusterRuntime(workers, ccfg, autoscaler=autoscaler,
                          sanitize=sanitize)
