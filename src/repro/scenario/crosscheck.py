"""Cross-fidelity consistency checks for a ``Scenario``.

One spec compiles to three fidelities (plan / engine / cluster) that share a
single resolution pass, so their *large-scale* answers must agree: the
cluster's delivered throughput should be the single-replica engine's times
the replica count (within fleet effects — routing skew, migration overhead,
queueing), and both should sit below the planner's analytical decode bound.
``crosscheck`` runs all three on a small closed-loop variant of the spec and
flags ratios outside per-scenario bounds as lint-style ``Finding`` rows —
the dynamic counterpart of ``Scenario.check()``'s static diagnostics: a
misconfiguration that each fidelity tolerates in isolation (a replica with a
starved KV pool, an absurd KV wire format, a routing policy fighting the
fleet shape) shows up as the fidelities disagreeing about the same spec.

Codes:

  XCHK000  the spec itself fails ``Scenario.check()`` (static errors)
  XCHK001  cluster throughput vs replica-scaled engine throughput
  XCHK002  cluster throughput vs the planner's analytical decode bound
  XCHK003  cluster mean TPOT vs engine mean TPOT
  XCHK004  cluster mean TTFT vs engine mean TTFT
  XCHK005  cluster goodput vs replica-scaled engine goodput

Ratios are always cluster / reference. Bounds are deliberately loose —
fleet effects are real physics, not noise — and per-scenario overrides
(``BOUNDS``) encode the shapes where a fidelity is structurally expected to
deviate further (disaggregation pays transfer; autoscaling changes the
replica count mid-run).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.lint.rules import Finding
from repro.scenario.spec import Scenario

# ratio -> (lo, hi), cluster / reference
DEFAULT_BOUNDS: Dict[str, Tuple[float, float]] = {
    "tput_vs_engine": (0.40, 2.00),
    "tput_vs_plan": (0.02, 1.50),
    "tpot_vs_engine": (0.40, 2.50),
    "ttft_vs_engine": (0.10, 6.00),
    "goodput_vs_engine": (0.30, 3.00),
}

# per-scenario overrides: shapes where a fidelity structurally deviates
BOUNDS: Dict[str, Dict[str, Tuple[float, float]]] = {
    # disaggregated: the engine fidelity is one colocated-style decode
    # replica, while the cluster adds dedicated prefill capacity and pays
    # KV transfer — throughput lands above the decode-pool-only scaling
    # and TTFT/TPOT shift with the migration path
    # (measured 2026-08: tput 0.53, ttft 2.4, goodput 0.12 at n=40 — the
    # closed burst funnels every request through the migration path, so
    # fleet TTFT-SLO goodput sits far below the replica-scaled engine's)
    "ds8b-4xh200-disagg": {
        "tput_vs_engine": (0.40, 3.00),
        "ttft_vs_engine": (0.05, 6.00),
        "goodput_vs_engine": (0.03, 3.00),
    },
    # autoscaling under a closed burst: the fleet grows past the initial
    # replica count the engine ratio is scaled by (measured: tput 0.62,
    # goodput 0.44, ttft 1.9)
    "ds8b-autoscale-diurnal": {
        "tput_vs_engine": (0.40, 4.00),
        "goodput_vs_engine": (0.30, 5.00),
    },
}

_CODES = {
    "tput_vs_engine": ("XCHK001", "cluster throughput vs replica-scaled "
                                  "engine throughput"),
    "tput_vs_plan": ("XCHK002", "cluster throughput vs planner decode "
                                "bound"),
    "tpot_vs_engine": ("XCHK003", "cluster mean TPOT vs engine mean TPOT"),
    "ttft_vs_engine": ("XCHK004", "cluster mean TTFT vs engine mean TTFT"),
    "goodput_vs_engine": ("XCHK005", "cluster goodput vs replica-scaled "
                                     "engine goodput"),
}


def bounds_for(name: str) -> Dict[str, Tuple[float, float]]:
    merged = dict(DEFAULT_BOUNDS)
    merged.update(BOUNDS.get(name, {}))
    return merged


def _closed_variant(sc: Scenario, n_requests: int) -> Scenario:
    """The spec with its traffic replaced by a small closed-loop burst:
    identical work across fidelities (same workload, same seed), no arrival
    process in the comparison."""
    traffic = dataclasses.replace(sc.traffic, process="closed",
                                  n_requests=n_requests, arrivals=())
    return dataclasses.replace(sc, traffic=traffic)


@dataclasses.dataclass(frozen=True)
class CrosscheckReport:
    """The measured ratios plus the findings they produced. ``ratios`` maps
    metric -> (ratio, cluster_value, reference_value); consult it when
    calibrating bounds for a new scenario."""
    scenario: str
    ratios: Dict[str, Tuple[float, float, float]]
    findings: Tuple[Finding, ...]

    @property
    def ok(self) -> bool:
        return not self.findings


def _run_engine(sc: Scenario, entries) -> Tuple[dict, Optional[dict]]:
    """One replica of the reference (decode-capable) group over its share of
    the closed trace. Returns (summary, slo_summary-or-None)."""
    ref = next((i for i, g in enumerate(sc.fleet) if g.role != "prefill"), 0)
    eng = sc.to_engine(group=ref)
    for e in entries:
        eng.submit(e.isl, e.osl, slo_class=e.slo_class)
    eng.run()
    summary = eng.metrics.summary()
    slos = sc.slo_map()
    slo_sum = eng.metrics.slo_summary(slos) if slos else None
    return summary, slo_sum


def _planner_tput(sc: Scenario) -> Optional[float]:
    """The analytical fleet decode-throughput bound: the spec's own fleet
    when it is a single group, the best feasible candidate plan for the
    device budget otherwise."""
    from repro.scenario.compile import estimate_fleet, to_plan
    if len(sc.fleet) == 1:
        est = estimate_fleet(sc)
        return est.decode_tput_tok_s if est.feasible else None
    ests = [e for e in to_plan(sc) if e.feasible]
    return ests[0].decode_tput_tok_s if ests else None


def crosscheck(sc: Scenario, n_requests: int = 40) -> CrosscheckReport:
    """Run all three fidelities on a closed-loop shrink of ``sc`` and
    compare. Returns a report whose ``findings`` are empty when every ratio
    sits inside ``bounds_for(sc.name)``."""
    static = sc.check()
    if static:
        findings = tuple(Finding(
            rule_id="XCHK000", path=f"scenario:{sc.name}", line=0,
            severity="error",
            message=f"spec fails static check, crosscheck skipped: "
                    f"{d.format()}") for d in static)
        return CrosscheckReport(scenario=sc.name, ratios={},
                                findings=findings)

    small = _closed_variant(sc, n_requests)
    from repro.scenario.compile import trace
    entries = trace(small)

    # cluster fidelity: the ground truth
    rt = small.to_cluster()
    rt.submit_trace(entries)
    m = rt.run()
    slos = small.slo_map()
    csum = m.summary(slos=slos or None)
    creq = m.request_summary()

    # engine fidelity: one reference replica over a 1/n_rep share
    n_rep = sum(g.count for g in small.fleet if g.role != "prefill")
    esum, eslo = _run_engine(small, entries[::max(n_rep, 1)])

    ratios: Dict[str, Tuple[float, float, float]] = {}

    def ratio(metric: str, cluster: float, reference: float):
        if reference <= 0 or cluster <= 0:
            return
        ratios[metric] = (cluster / reference, cluster, reference)

    ratio("tput_vs_engine", csum["throughput_tok_s"],
          esum["gen_throughput_tok_s"] * n_rep)
    plan_tput = _planner_tput(small)
    if plan_tput:
        ratio("tput_vs_plan", csum["throughput_tok_s"], plan_tput)
    ratio("tpot_vs_engine", creq["tpot_s"]["mean"], esum["tpot_s"]["mean"])
    ratio("ttft_vs_engine", creq["ttft_s"]["mean"], esum["ttft_s"]["mean"])
    if slos and eslo is not None and "goodput_tok_s" in csum:
        # scale the replica's goodput to the fleet; skip when either side
        # attains nothing (a 0/0 ratio says nothing about consistency)
        ratio("goodput_vs_engine", csum["goodput_tok_s"],
              eslo["goodput_tok_s"] * n_rep)

    bounds = bounds_for(sc.name)
    findings: List[Finding] = []
    for metric, (r, cv, rv) in sorted(ratios.items()):
        lo, hi = bounds[metric]
        if not lo <= r <= hi:
            code, label = _CODES[metric]
            findings.append(Finding(
                rule_id=code, path=f"scenario:{sc.name}", line=0,
                severity="error",
                message=f"{label}: ratio {r:.3f} outside [{lo}, {hi}] "
                        f"(cluster {cv:.3f} vs reference {rv:.3f}, "
                        f"n_requests={n_requests})"))
    return CrosscheckReport(scenario=sc.name, ratios=ratios,
                            findings=tuple(findings))
