"""Named paper scenarios — the §III-C testbed as reusable specs.

One entry per evaluated model family (8B / 14B / 32B / 405B / R1-671B), each
pinned to the deployment the paper found best on 8xH200 (tests/test_planner
regression points), plus the 4xH200 colocated-vs-disaggregated pair the
cluster benchmarks sweep. Sweeps iterate these (via ``dataclasses.replace``
for rate/size variants) instead of copy-pasting engine kwargs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.perf_model import ParallelismPlan
from repro.scenario.spec import (Autoscaler, ModelRef, Rebalance, Scenario,
                                 SLOClass, Traffic, WorkerGroup)

INTERACTIVE = SLOClass(name="interactive", ttft_s=0.5, tpot_s=0.020,
                       priority=10)
BATCH = SLOClass(name="batch", ttft_s=30.0, tpot_s=0.5, priority=0)

# the paper's offline-throughput workload: Natural-Reasoning lengths,
# everything submitted at once (§III-B)
_REASONING_CLOSED = Traffic(process="closed", workload="reasoning",
                            n_requests=2000, seed=0)

# the serving-level workload the cluster layer sweeps: kilotoken prompts,
# capped reasoning decodes, open-loop Poisson arrivals past the colocated
# fleet's capacity knee
_LONG_OPEN = Traffic(process="poisson", rate=12.0, workload="long_reasoning",
                     n_requests=150, osl_cap=1200, seed=42)


SCENARIOS: Dict[str, Scenario] = {s.name: s for s in (
    # ---- cluster serving pair (disagg_sweep / serve_cluster) --------------
    Scenario(
        name="ds8b-4xh200-colocated",
        model=ModelRef("ds-distill-8b"),
        fleet=(WorkerGroup(role="colocated", count=4, n_pages=3000,
                           max_seqs=64, prefix="co"),),
        traffic=_LONG_OPEN,
        slos=(INTERACTIVE,),
        notes="4 DP replicas, prefill+decode interleaved (paper §V-B "
              "baseline); 48k KV tokens/worker saturates at paper-like "
              "scale"),
    Scenario(
        name="ds8b-4xh200-disagg",
        model=ModelRef("ds-distill-8b"),
        fleet=(WorkerGroup(role="prefill", count=1, n_pages=3000,
                           max_seqs=64, prefix="pre"),
               WorkerGroup(role="decode", count=3, n_pages=3000,
                           max_seqs=64, prefix="dec")),
        traffic=_LONG_OPEN,
        slos=(INTERACTIVE,),
        notes="same 4 devices split 1 prefill + 3 decode with modeled "
              "KV-transfer migration (§III phase divergence made "
              "structural)"),
    # ---- mixed tenancy: interactive + batch on one fleet (slo_tiers) ------
    Scenario(
        name="ds8b-4xh200-mixed",
        model=ModelRef("ds-distill-8b"),
        fleet=(WorkerGroup(role="colocated", count=4, n_pages=3000,
                           max_seqs=64, prefix="co"),),
        traffic=dataclasses.replace(
            _LONG_OPEN, class_mix=(("interactive", 0.4), ("batch", 0.6))),
        slos=(INTERACTIVE, BATCH),
        class_kv_headroom=0.10,
        notes="multi-tenant SLO classes: interactive jumps queues and keeps "
              "a 10% KV slice, batch absorbs backpressure — the fleet-level "
              "latency-vs-throughput tier trade-off (benchmarks/slo_tiers)"),
    # ---- elastic sizing under diurnal load (benchmarks/autoscale) ---------
    Scenario(
        name="ds8b-autoscale-diurnal",
        model=ModelRef("ds-distill-8b"),
        fleet=(WorkerGroup(role="colocated", count=2, n_pages=3000,
                           max_seqs=64, prefix="co"),),
        traffic=Traffic(process="piecewise", workload="long_reasoning",
                        phases=((20.0, 2.0), (15.0, 10.0), (30.0, 2.0)),
                        n_requests=200, osl_cap=1200, seed=42),
        slos=(INTERACTIVE,),
        autoscaler=Autoscaler(policy="slo_guard", role="colocated",
                              min_workers=2, max_workers=6, tick_s=1.0,
                              cooldown_s=4.0, ewma_alpha=0.7),
        notes="trough-provisioned fleet (2 replicas) rides a 5x diurnal "
              "swing: the slo_guard controller grows toward peak and shrinks "
              "back, holding attainment at peak-fleet level on a fraction of "
              "the worker-seconds (the fixed-degree utilization gap the "
              "paper's fleet sizing discussion leaves on the table)"),
    # ---- decode→decode rebalancing (benchmarks/rebalance) -----------------
    Scenario(
        name="ds8b-4xh200-rebalance",
        model=ModelRef("ds-distill-8b"),
        fleet=(WorkerGroup(role="prefill", count=1, n_pages=3000,
                           max_seqs=64, prefix="pre"),
               WorkerGroup(role="decode", count=3, n_pages=3000,
                           max_seqs=64, prefix="dec")),
        traffic=dataclasses.replace(_LONG_OPEN, rate=14.0),
        slos=(INTERACTIVE,),
        rebalance=Rebalance(policy="kv_pressure"),
        notes="the disagg fleet driven past its capacity knee, with "
              "KV-pressure rebalancing shedding load off the first decode "
              "worker to saturate (Obs 4: the fleet tail is set by that "
              "worker's preemption storm; benchmarks/rebalance compares "
              "against the same fleet with the hook disabled)"),
    # ---- the 8xH200 testbed points (one per model family) -----------------
    Scenario(
        name="ds8b-8xh200-dp8",
        model=ModelRef("ds-distill-8b"),
        fleet=(WorkerGroup(role="colocated", count=8),),
        traffic=_REASONING_CLOSED,
        slos=(BATCH,),
        notes="Obs 5: pure DP wins for small dense models"),
    Scenario(
        name="ds14b-8xh200-dp8",
        model=ModelRef("ds-distill-14b"),
        fleet=(WorkerGroup(role="colocated", count=8),),
        traffic=_REASONING_CLOSED,
        slos=(BATCH,),
        notes="Obs 5: DP8 beats every TP/PP mix at 14B"),
    Scenario(
        name="ds32b-8xh200-dp4tp2",
        model=ModelRef("ds-distill-32b"),
        fleet=(WorkerGroup(role="colocated", count=4,
                           plan=ParallelismPlan(tp=2, ep=2)),),
        traffic=_REASONING_CLOSED,
        slos=(BATCH,),
        notes="the right-sized-TP point: DP4xTP2 beats DP8 and TP8 "
              "(KV capacity vs weight replication trade-off)"),
    Scenario(
        name="llama405b-8xh200-tp8",
        model=ModelRef("llama3-405b"),
        fleet=(WorkerGroup(role="colocated", count=1,
                           plan=ParallelismPlan(tp=8, ep=8)),),
        traffic=_REASONING_CLOSED,
        slos=(BATCH,),
        notes="§V-C: TP8 wins at 405B; PP8 catastrophic (KV-starved "
              "bubbles)"),
    Scenario(
        name="r1-8xh200-pp4tp2",
        model=ModelRef("deepseek-r1-671b", dtype_bytes=1),  # fp8 weights
        fleet=(WorkerGroup(role="colocated", count=1,
                           plan=ParallelismPlan(tp=2, pp=4, ep=2)),),
        traffic=_REASONING_CLOSED,
        slos=(BATCH,),
        notes="Obs 6: sync-latency-bound sparse model prefers PP4xTP2 "
              "over TP8"),
)}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r} "
                       f"(have {sorted(SCENARIOS)})") from None


def register_scenario(sc: Scenario, overwrite: bool = False):
    if sc.name in SCENARIOS and not overwrite:
        raise ValueError(f"scenario {sc.name!r} already registered")
    SCENARIOS[sc.name] = sc


def variant(name: str, **changes) -> Scenario:
    """A registry scenario with top-level fields replaced (sweep helper)."""
    return dataclasses.replace(get_scenario(name), **changes)
